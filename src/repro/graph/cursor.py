"""DeriveCursor: the worker's durable progress record.

Mirrors the ``RunManifest`` commit style exactly: small, versioned,
conditionally-written entries at ``<output stream>/derive/<seq>.dc``, each
binding in **one object-store commit**:

  * the source cursor (source stream steps consumed through), and
  * the output sequence (producer offsets published through).

A crash anywhere between derive and cursor commit replays the window from
the last committed entry: already-uploaded outputs are found by content
address (upload skipped), already-committed offsets are deduplicated by the
manifest's producer state map — so the replay is deterministic and
exactly-once with no coordination. Monotone sequence numbers are claimed by
conditional put; a zombie incarnation that lost a race is fenced by the
regression check (its source cursor would roll progress backward).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional

import msgpack

from repro.core.objectstore import Namespace, NoSuchKey

__all__ = ["DERIVE_SCHEMA", "DERIVE_DIR", "DeriveCursor", "DeriveCursorError",
           "DeriveCursorStore"]

#: wire-format schema tag; bump on incompatible changes
DERIVE_SCHEMA = 1
#: directory component under the output stream namespace
DERIVE_DIR = "derive"


class DeriveCursorError(ValueError):
    """A derive cursor entry is missing, malformed, or from an unknown schema."""


@dataclass(frozen=True)
class DeriveCursor:
    """One committed derive-progress record."""

    seq: int            # monotone commit sequence (the object key)
    src_step: int       # source stream steps consumed through (exclusive)
    out_seq: int        # next producer offset the worker will publish at
    graph: str          # OpGraph.graph_hash() this progress belongs to
    op: str             # fused chain signature, e.g. "filter@1>pack@1"
    worker_id: str = "" # last incarnation to commit (diagnostic only)

    def pack(self) -> bytes:
        return msgpack.packb({
            "schema": DERIVE_SCHEMA,
            "seq": self.seq,
            "src": self.src_step,
            "out": self.out_seq,
            "graph": self.graph,
            "op": self.op,
            "worker": self.worker_id,
        }, use_bin_type=True)

    @staticmethod
    def unpack(raw: bytes) -> "DeriveCursor":
        try:
            d = msgpack.unpackb(raw, raw=False)
        except Exception as e:
            raise DeriveCursorError(
                f"undecodable derive cursor: {type(e).__name__}: {e}") from e
        if not isinstance(d, dict) or "schema" not in d:
            raise DeriveCursorError("derive cursor carries no schema tag")
        if d["schema"] != DERIVE_SCHEMA:
            raise DeriveCursorError(
                f"derive cursor schema {d['schema']!r} is not supported by "
                f"this build (expected {DERIVE_SCHEMA})")
        try:
            return DeriveCursor(seq=d["seq"], src_step=d["src"],
                                out_seq=d["out"], graph=d["graph"],
                                op=d["op"], worker_id=d.get("worker", ""))
        except KeyError as e:
            raise DeriveCursorError(f"derive cursor missing field {e}") from e


class DeriveCursorStore:
    """Reads and conditionally commits derive cursors of one derived stream."""

    def __init__(self, ns: Namespace):
        self.ns = ns
        self.store = ns.store

    def key(self, seq: int) -> str:
        return self.ns.key(DERIVE_DIR, f"{seq:08d}.dc")

    def seqs(self) -> List[int]:
        out = []
        for key in self.store.list(self.ns.key(DERIVE_DIR)):
            try:
                out.append(int(key.rsplit("/", 1)[-1].split(".")[0]))
            except ValueError:
                pass
        return sorted(out)

    def read(self, seq: int) -> DeriveCursor:
        try:
            raw = self.store.get(self.key(seq))
        except (KeyError, NoSuchKey) as e:
            raise DeriveCursorError(f"no derive cursor seq={seq}") from e
        return DeriveCursor.unpack(raw)

    def latest(self) -> Optional[DeriveCursor]:
        seqs = self.seqs()
        if not seqs:
            return None
        return self.read(seqs[-1])

    def commit(self, dc: DeriveCursor) -> bool:
        """Claim ``dc.seq`` with a conditional put. False = another worker
        incarnation won that sequence number."""
        return self.store.put_if_absent(self.key(dc.seq), dc.pack())

    def append(self, *, src_step: int, out_seq: int, graph: str, op: str,
               worker_id: str = "", max_attempts: int = 16) -> DeriveCursor:
        """Commit the next entry (monotone seq claim + regression fencing).

        A candidate whose source cursor sits behind the committed head is a
        zombie incarnation resurfacing after a replacement advanced the
        stream — refused, exactly like a regressive RunManifest entry.
        """
        candidate = DeriveCursor(seq=0, src_step=src_step, out_seq=out_seq,
                                 graph=graph, op=op, worker_id=worker_id)
        for _ in range(max_attempts):
            seqs = self.seqs()
            seq = (seqs[-1] + 1) if seqs else 0
            if seqs:
                head = self.read(seqs[-1])
                if head.graph != graph:
                    raise DeriveCursorError(
                        f"derive cursor chain belongs to graph "
                        f"{head.graph[:12]}…, not {graph[:12]}… — bump the op "
                        f"version and derive into a fresh stream instead of "
                        f"mixing graphs in one output")
                if candidate.src_step < head.src_step:
                    raise DeriveCursorError(
                        f"refusing to commit a regressive derive cursor: "
                        f"candidate src_step {candidate.src_step} < committed "
                        f"{head.src_step} (seq {head.seq}) — is a replaced "
                        f"worker incarnation still running?")
            dc = replace(candidate, seq=seq)
            if self.commit(dc):
                return dc
        raise DeriveCursorError(
            f"could not claim a derive cursor sequence number after "
            f"{max_attempts} attempts (is another worker committing?)")
