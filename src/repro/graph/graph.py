"""OpGraph: a DAG of named ops whose edges are streams.

Every op consumes one named stream and produces one named stream. Streams
produced by a ``PackOp`` are **materialized** — real TGB streams under the
run namespace, published through the ordinary producer commit protocol and
readable by any consumer. Streams produced by row ops are **virtual** edges:
they exist only as typing between fused stages, because a TGB stream is by
definition a packed token grid — the only way to materialize rows is to
pack them. The executor therefore fuses each materialized output's chain of
row ops back to its source stream and runs the whole chain in one
``DeriveWorker`` pass; fan-out (several ops reading one stream) simply
yields several chains.

``graph_hash()`` canonically hashes the whole structure (every op's id,
version, params hash, and wiring), so the same op in a different graph
derives under a different content address — lineage is pinned to the graph
that produced it, per the reproducible-pipelines design.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.graph.ops import PackOp, chain_params_hash, chain_signature
from repro.graph.provenance import _canonical

__all__ = ["GraphError", "OpGraph", "DeriveChain"]


class GraphError(ValueError):
    """The op graph is structurally invalid (cycle, clash, dangling edge)."""


@dataclass(frozen=True)
class DeriveChain:
    """One executable unit: source stream -> fused row ops -> PackOp -> output."""

    source: str                 # input stream name (external to the graph)
    output: str                 # materialized output stream name
    ops: Tuple[object, ...]     # row ops in order, terminal PackOp last

    @property
    def pack(self) -> PackOp:
        return self.ops[-1]

    @property
    def signature(self) -> str:
        return chain_signature(self.ops)

    @property
    def params_hash(self) -> str:
        return chain_params_hash(self.ops)


class OpGraph:
    """A DAG of named ops; edges are stream names."""

    def __init__(self, name: str = "graph"):
        self.name = name
        # output stream name -> (op, source stream name)
        self._nodes: Dict[str, Tuple[object, str]] = {}

    def add(self, op, *, source: str, output: str) -> "OpGraph":
        """Wire ``op`` to consume stream ``source`` and produce ``output``."""
        if not source or not output:
            raise GraphError("source/output stream names must be non-empty")
        if source == output:
            raise GraphError(f"op {op.op_id!r}: source == output ({source!r})")
        if output in self._nodes:
            raise GraphError(f"stream {output!r} already has a producer op "
                             f"({self._nodes[output][0].op_id!r})")
        self._nodes[output] = (op, source)
        self._check_acyclic()
        return self

    def _check_acyclic(self) -> None:
        for start in self._nodes:
            seen = set()
            cur = start
            while cur in self._nodes:
                if cur in seen:
                    raise GraphError(f"cycle through stream {cur!r}")
                seen.add(cur)
                cur = self._nodes[cur][1]

    # -- structure queries ----------------------------------------------------
    @property
    def sources(self) -> List[str]:
        """Stream names consumed but never produced: the graph's inputs."""
        produced = set(self._nodes)
        return sorted({src for _, src in self._nodes.values()}
                      - produced)

    @property
    def outputs(self) -> List[str]:
        """Materialized output stream names (produced by a PackOp)."""
        return sorted(out for out, (op, _) in self._nodes.items()
                      if isinstance(op, PackOp))

    def chain(self, output: str) -> DeriveChain:
        """Resolve the fused chain producing materialized stream ``output``."""
        if output not in self._nodes:
            raise GraphError(f"no op produces stream {output!r}")
        ops: List[object] = []
        cur = output
        while cur in self._nodes:
            op, src = self._nodes[cur]
            if ops and isinstance(op, PackOp):
                raise GraphError(
                    f"stream {cur!r} is materialized (PackOp output) but is "
                    f"consumed by a fused row chain; derive it with its own "
                    f"worker and feed the downstream graph from it")
            ops.append(op)
            cur = src
        ops.reverse()
        if not isinstance(ops[-1], PackOp):
            raise GraphError(
                f"stream {output!r} is a virtual (row) edge; only PackOp "
                f"outputs materialize — terminate the chain with a PackOp")
        return DeriveChain(source=cur, output=output, ops=tuple(ops))

    def chains(self) -> List[DeriveChain]:
        return [self.chain(out) for out in self.outputs]

    # -- identity -------------------------------------------------------------
    def graph_hash(self) -> str:
        """Canonical hash of the whole DAG structure + every op's identity."""
        doc = {
            "name": self.name,
            "nodes": {
                out: {
                    "op": f"{op.op_id}@{op.version}",
                    "params": chain_params_hash([op]),
                    "source": src,
                }
                for out, (op, src) in self._nodes.items()
            },
        }
        return hashlib.sha256(_canonical(doc)).hexdigest()

    def __repr__(self) -> str:
        edges = ", ".join(f"{src}-[{op.op_id}]->{out}"
                          for out, (op, src) in sorted(self._nodes.items()))
        return f"OpGraph({self.name!r}: {edges})"
