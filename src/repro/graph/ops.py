"""The op vocabulary of the derive graph.

Ops transform *rows* of the token grid: a source TGB decodes to a
``(global_batch, seq_len)`` int32 array and each row flows through the chain
as one record. Row ops (``MapOp``/``FilterOp``/``DedupOp``) are pure
functions of their input rows — that determinism is what makes derived
outputs content-addressable. ``PackOp`` is the terminal, materializing
stage: it re-packs surviving rows into output global batches through
``GlobalBatchPacker`` (possibly at a different D x C / grid shape) and pads
the final partial batch via ``flush(pad_token)`` when the source stream is
exhausted.

A model-scored stage (quality filter, reward scorer) is just a ``BatchOp``
whose ``process`` calls the model; ``version`` and ``params`` pin the model
identity so a weight bump re-derives under a new content address.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Protocol, runtime_checkable

import hashlib

import numpy as np

from repro.data.packing import GlobalBatchPacker, PackedBatch
from repro.graph.provenance import params_hash

__all__ = ["BatchOp", "RowOp", "MapOp", "FilterOp", "DedupOp", "PackOp"]


@runtime_checkable
class BatchOp(Protocol):
    """Structural protocol every graph stage satisfies.

    ``op_id`` names the stage, ``version`` pins its implementation (bump it
    whenever the transformation changes — outputs re-derive under a new
    content address), ``params()`` is the canonicalized configuration that
    feeds the provenance hash.
    """

    op_id: str
    version: int

    def params(self) -> dict:
        ...

    def process(self, rows: np.ndarray) -> np.ndarray:
        """Transform a block of rows; returns the surviving/transformed rows
        (row ops only — ``PackOp`` materializes instead)."""
        ...


class RowOp:
    """Base for row-wise stages: identity process, shared signature bits."""

    def __init__(self, op_id: str, version: int = 1,
                 params: Optional[dict] = None):
        if not op_id or "/" in op_id or ">" in op_id:
            raise ValueError(f"bad op_id {op_id!r} (no '/', no '>')")
        self.op_id = op_id
        self.version = version
        self._params = dict(params or {})

    @property
    def signature(self) -> str:
        return f"{self.op_id}@{self.version}"

    def params(self) -> dict:
        return dict(self._params)

    def process(self, rows: np.ndarray) -> np.ndarray:
        return rows

    def reset(self) -> None:
        """Clear any per-quantum state (called at each derive-quantum
        boundary so replays are deterministic from the committed cursor)."""

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.signature})"


class MapOp(RowOp):
    """Apply ``fn(rows) -> rows`` to every block (vectorized row map).

    ``fn`` must be pure and length-preserving; anything it is parameterized
    by belongs in ``params`` so the content address tracks it.
    """

    def __init__(self, op_id: str, fn: Callable[[np.ndarray], np.ndarray],
                 version: int = 1, params: Optional[dict] = None):
        super().__init__(op_id, version, params)
        self.fn = fn

    def process(self, rows: np.ndarray) -> np.ndarray:
        out = np.asarray(self.fn(rows))
        if out.shape != rows.shape:
            raise ValueError(
                f"{self.signature}: map must preserve the row grid shape, "
                f"got {rows.shape} -> {out.shape}")
        return out


class FilterOp(RowOp):
    """Keep rows where ``predicate(rows) -> bool mask`` is True."""

    def __init__(self, op_id: str, predicate: Callable[[np.ndarray], np.ndarray],
                 version: int = 1, params: Optional[dict] = None):
        super().__init__(op_id, version, params)
        self.predicate = predicate

    def process(self, rows: np.ndarray) -> np.ndarray:
        mask = np.asarray(self.predicate(rows), dtype=bool)
        if mask.shape != (rows.shape[0],):
            raise ValueError(
                f"{self.signature}: predicate must yield one bool per row, "
                f"got shape {mask.shape} for {rows.shape[0]} rows")
        return rows[mask]


class DedupOp(RowOp):
    """Drop exact-duplicate rows (first occurrence wins).

    Dedup scope is one *derive quantum* (the window of source TGBs between
    two cursor commits): the seen-set resets at every quantum boundary, so a
    worker replaying from its committed cursor reproduces the output
    byte-identically without any persisted dedup state.
    """

    def __init__(self, op_id: str = "dedup", version: int = 1,
                 params: Optional[dict] = None):
        super().__init__(op_id, version, params)
        self._seen: set = set()

    def reset(self) -> None:
        self._seen.clear()

    def process(self, rows: np.ndarray) -> np.ndarray:
        keep = []
        for i in range(rows.shape[0]):
            h = hashlib.sha256(np.ascontiguousarray(rows[i]).tobytes()).digest()
            if h in self._seen:
                continue
            self._seen.add(h)
            keep.append(i)
        if len(keep) == rows.shape[0]:
            return rows
        return rows[keep]


class PackOp(RowOp):
    """Terminal stage: re-pack surviving rows into output global batches.

    Wraps ``data.packing.GlobalBatchPacker``. The output grid shape and
    D x C layout are the op's parameters (they determine output bytes, so
    they feed the content address). ``flush()`` pads and emits the final
    partial batch — invoked by the worker at every derive-quantum boundary
    (which includes source-stream exhaustion), keeping packer state from
    ever crossing a cursor commit.
    """

    def __init__(self, op_id: str, global_batch: int, seq_len: int,
                 dp: int = 1, cp: int = 1, pad_token: int = 0,
                 version: int = 1):
        super().__init__(op_id, version, params={
            "global_batch": global_batch, "seq_len": seq_len,
            "dp": dp, "cp": cp, "pad_token": pad_token})
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.dp = dp
        self.cp = cp
        self.pad_token = pad_token
        self._packer: Optional[GlobalBatchPacker] = None

    def _ensure(self) -> GlobalBatchPacker:
        if self._packer is None:
            self._packer = GlobalBatchPacker(self.global_batch, self.seq_len,
                                             self.dp, self.cp)
        return self._packer

    def reset(self) -> None:
        self._packer = None

    def pack_rows(self, rows: np.ndarray) -> List[PackedBatch]:
        if rows.size == 0:
            return []
        # one packer "sample" per surviving source row: num_samples on the
        # output TGB counts contributing source rows
        return self._ensure().add_tokens(rows.ravel(), samples=rows.shape[0])

    def flush(self) -> Optional[PackedBatch]:
        """Source exhausted (or quantum boundary): pad + emit the remainder
        via the packer's end-of-stream flush semantics."""
        if self._packer is None:
            return None
        return self._packer.flush(pad_token=self.pad_token)


def chain_signature(ops) -> str:
    """The fused chain's identity string: ``"filter@1>pack@2"``."""
    return ">".join(op.signature for op in ops)


def chain_params_hash(ops) -> str:
    """One canonical hash over every stage's parameters, keyed by stage."""
    return params_hash({op.signature: op.params() for op in ops})
