"""Derived-stream transformation DAG with content-addressed provenance.

``OpGraph`` wires ops (``MapOp``/``FilterOp``/``DedupOp``/``PackOp``) into a
DAG whose edges are streams; ``DeriveWorker`` executes one fused chain,
consuming source TGBs through the ordinary consumer read path and publishing
derived TGBs through the ordinary producer commit protocol. Every derived
TGB carries a canonical ``Provenance`` record and is content-addressed by
its hash; worker progress is one conditional-put ``DeriveCursor`` per
window. Together these make re-derivation exactly-once as a *storage*
property: replays find their outputs already present and skip them.
"""
from repro.graph.cursor import (DERIVE_DIR, DERIVE_SCHEMA, DeriveCursor,
                                DeriveCursorError, DeriveCursorStore)
from repro.graph.graph import DeriveChain, GraphError, OpGraph
from repro.graph.ops import (BatchOp, DedupOp, FilterOp, MapOp, PackOp, RowOp,
                             chain_params_hash, chain_signature)
from repro.graph.provenance import PROV_SCHEMA, Provenance, params_hash
from repro.graph.worker import DeriveStats, DeriveWorker

__all__ = [
    "BatchOp", "RowOp", "MapOp", "FilterOp", "DedupOp", "PackOp",
    "chain_signature", "chain_params_hash",
    "OpGraph", "DeriveChain", "GraphError",
    "Provenance", "PROV_SCHEMA", "params_hash",
    "DeriveCursor", "DeriveCursorStore", "DeriveCursorError",
    "DERIVE_SCHEMA", "DERIVE_DIR",
    "DeriveWorker", "DeriveStats",
]
