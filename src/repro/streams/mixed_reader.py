"""MixedReader: one rank's multiplexed view over N named TGB streams.

Implements the facade ``BatchReader`` protocol. Each global step g is routed
to the stream the ``MixPlan`` schedules there; because per-stream steps are
dense and ordered, every underlying single-stream consumer just advances its
normal ``<V, S>`` cursor — the mixing layer adds no new read path, only
routing.

Exactly-once across streams: ``checkpoint()`` emits one composite token
carrying the mix position (in **materialized mix units**, invariant under
topology resize) plus every stream's ``<V, S>`` cursor; ``restore()``
re-validates that the per-stream cursors are exactly what the
(weights, seed) schedule implies at that mix position, so a token captured
under different mix settings can never silently misalign the streams.

Elastic topology restore (§4.1): when the consuming mesh's DP degree differs
from the materialized layout's by an integer factor, the reader runs in
*elastic mode* — the core ``remap_step`` is applied at the mixing layer
(treating the mixed schedule as one virtual TGB stream at the materialized
D x C), so each rank still issues exactly one slice read per logical step
and the concatenated global batch byte sequence is identical to the
un-resized run's. The schedule itself is consumed in materialized units and
therefore never re-interleaves.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional

from repro.core.consumer import (MeshPosition, convert_logical_step,
                                 floor_to_data_step, remap_step)
from repro.core.objectstore import IOPool, Namespace
from repro.dataplane.tgb_backend import TGBBatchReader
from repro.dataplane.types import (Batch, Checkpoint, Topology,
                                   UnsupportedOperation)
from repro.streams.mixplan import MixPlan

__all__ = ["MixedReader"]


class MixedReader:
    """Facade reader multiplexing per-stream consumers via a MixPlan."""

    def __init__(self, plan: MixPlan, stream_namespaces: Mapping[str, Namespace],
                 topology: Topology, dp_rank: int, cp_rank: int, *,
                 prefetch_depth: int = 4, dense_read: bool = False,
                 verify_crc: bool = True,
                 io_pool: Optional[IOPool] = None,
                 resume: "Checkpoint | str | None" = None,
                 data_topology: Optional[Topology] = None):
        self.plan = plan
        self.topology = topology
        self.data_topology = data_topology or topology
        self.dp_rank, self.cp_rank = dp_rank, cp_rank
        self._elastic = self.data_topology.dp != topology.dp
        if self._elastic:
            if self.data_topology.cp != topology.cp:
                raise UnsupportedOperation(
                    "elastic multi-stream restore supports factor DP resize "
                    "only; CP must match the materialized layout "
                    f"(cp={self.data_topology.cp}, got cp={topology.cp})")
            if max(topology.dp, self.data_topology.dp) % \
                    min(topology.dp, self.data_topology.dp):
                raise UnsupportedOperation(
                    f"DP resize {self.data_topology.dp} -> {topology.dp} is "
                    f"not an integer factor")
        # one IOPool shared by every stream's consumer: N streams multiplex
        # one bounded in-flight request budget instead of N independent ones
        self.io_pool = io_pool or IOPool.default()
        # sub-readers run at the MATERIALIZED layout; in elastic mode their
        # (d, c) coordinates are re-derived per read by the mixing-layer remap
        sub_topo = self.data_topology
        self._subs: Dict[str, TGBBatchReader] = {
            name: TGBBatchReader(stream_namespaces[name], sub_topo,
                                 dp_rank if not self._elastic else 0,
                                 cp_rank,
                                 prefetch_depth=prefetch_depth,
                                 dense_read=dense_read,
                                 verify_crc=verify_crc,
                                 io_pool=self.io_pool,
                                 # stream-qualified registry instance so N
                                 # streams of one rank never collide into
                                 # auto-suffixed scopes
                                 stats_instance=f"{name}-d{dp_rank}c{cp_rank}")
            for name in plan.names
        }
        self.global_step = 0  # next mixed step this reader will return
        ckpt = Checkpoint.coerce(resume)
        if ckpt is not None:
            self.restore(ckpt)

    # -- mix-unit position ----------------------------------------------------
    def _mix_pos(self) -> int:
        """The cursor in materialized mix units (== ``global_step`` when the
        consuming topology matches the materialized layout)."""
        if not self._elastic:
            return self.global_step
        try:
            return convert_logical_step(self.global_step, self.topology.dp,
                                        self.data_topology.dp)
        except ValueError as e:
            raise UnsupportedOperation(
                f"mixed cursor at logical step {self.global_step} "
                f"(dp={self.topology.dp}) does not sit on a materialized "
                f"(dp={self.data_topology.dp}) global-batch boundary: {e}"
            ) from e

    # -- reads ----------------------------------------------------------------
    def next_batch(self, timeout_s: Optional[float] = None) -> Batch:
        if self._elastic:
            return self._next_batch_elastic(timeout_s)
        name, stream_step = self.plan.position(self.global_step)
        sub = self._subs[name]
        if sub.consumer.step != stream_step:
            raise RuntimeError(
                f"stream {name!r} cursor {sub.consumer.step} diverged from "
                f"schedule step {stream_step} at global step "
                f"{self.global_step}; restore from a composite checkpoint")
        inner = sub.next_batch(timeout_s=timeout_s)
        batch = Batch.build(inner.payload, step=self.global_step,
                            version=inner.version, dp_rank=self.dp_rank,
                            cp_rank=self.cp_rank, topology=self.topology,
                            stream=name)
        self.global_step += 1
        return batch

    def _next_batch_elastic(self, timeout_s: Optional[float]) -> Batch:
        """One logical step on a factor-resized mesh: remap this rank onto
        the virtual mixed TGB stream, route the resulting materialized
        position through the schedule, and read that one slice."""
        ddp, dcp = self.data_topology.dp, self.data_topology.cp
        m, td, tc = remap_step(
            self.global_step,
            MeshPosition(self.dp_rank, self.cp_rank,
                         self.topology.dp, self.topology.cp),
            ddp, dcp)
        name, stream_m = self.plan.position(m)
        cons = self._subs[name].consumer
        # reposition the materialized-layout consumer at this read's exact
        # (tgb step, slice); its internal remap is then the identity
        cons.pos = MeshPosition(td, tc, ddp, dcp)
        cons.step = stream_m
        payload = cons.next_batch(timeout_s=timeout_s)
        batch = Batch.build(payload, step=self.global_step,
                            version=cons.view.version, dp_rank=self.dp_rank,
                            cp_rank=self.cp_rank, topology=self.topology,
                            stream=name)
        self.global_step += 1
        return batch

    # -- cursor ----------------------------------------------------------------
    def checkpoint(self) -> Checkpoint:
        """Composite token: mix position + every stream's <V, S> cursor.

        Stream cursors and the mix position are emitted in materialized
        units, which makes the token restorable on any integer-factor DP
        resize of the mesh (``step`` stays this reader's logical step)."""
        m = self._mix_pos()
        counts = self.plan.stream_counts(m)
        rows = []
        for name in self.plan.names:
            v = self._subs[name].consumer.view.version
            rows.append((name, v, counts[name]))
        return Checkpoint("tgb", version=-1, step=self.global_step,
                          streams=tuple(rows),
                          topology=(self.topology.dp, self.topology.cp),
                          data_dp=self.data_topology.dp, mix_pos=m)

    def restore(self, ckpt: "Checkpoint | str") -> None:
        ckpt = Checkpoint.coerce(ckpt)
        if ckpt.backend != "tgb":
            raise ValueError(f"cannot restore a {ckpt.backend!r} checkpoint "
                             f"on a tgb mixed reader")
        if not ckpt.composite:
            raise ValueError("single-stream checkpoint cannot be restored on "
                             "a multi-stream reader")
        names = tuple(sorted(row[0] for row in ckpt.streams))
        if names != self.plan.names:
            raise ValueError(
                f"checkpoint streams {names} do not match session streams "
                f"{self.plan.names}")
        # the mix position in materialized units; tokens minted before the
        # elastic-restore work (or hand-built ones) carry it as `step`
        m = ckpt.mix_pos if ckpt.mix_pos is not None else ckpt.step
        # the schedule is pure in (weights, seed, step): per-stream cursors
        # MUST equal the scheduled counts at the mix position, otherwise the
        # token was captured under different mix settings
        expect = self.plan.stream_counts(m)
        for name, _v, s in ckpt.streams:
            if s != expect[name]:
                raise ValueError(
                    f"composite checkpoint is inconsistent with this "
                    f"session's MixPlan: stream {name!r} cursor {s} != "
                    f"scheduled count {expect[name]} at mix step {m} "
                    f"(were weights/seed changed?)")
        try:
            logical = convert_logical_step(m, self.data_topology.dp,
                                           self.topology.dp)
        except ValueError as e:
            raise UnsupportedOperation(
                f"cannot restore mix position {m} "
                f"(dp={self.data_topology.dp} units) on a "
                f"dp={self.topology.dp} mesh: {e}. Supported elastic path: "
                f"integer-factor DP resize with the checkpoint on a "
                f"global-batch boundary of the new degree") from e
        for name, v, _s in ckpt.streams:
            self._subs[name].consumer.restore_cursor(v, expect[name])
        self.global_step = logical

    # -- progress probes --------------------------------------------------------
    def poll(self) -> bool:
        """Probe all streams for newly committed manifests."""
        advanced = False
        for sub in self._subs.values():
            advanced |= sub.poll()
        return advanced

    @property
    def published_steps(self) -> int:
        """Contiguous global steps currently servable: the first global step
        whose owning stream has not yet published the scheduled stream step.
        Anchored at this reader's cursor — everything below it was served.
        In elastic mode the frontier is computed in materialized units and
        floored to logical steps."""
        published = {name: sub.published_steps
                     for name, sub in self._subs.items()}
        m_frontier = self.plan.frontier(published, start=self._mix_floor())
        if not self._elastic:
            return m_frontier
        return floor_to_data_step(m_frontier, self.data_topology.dp,
                                  self.topology.dp)

    def stream_lag(self) -> Dict[str, int]:
        """Per-stream backlog: published-but-unconsumed stream steps (in
        materialized units)."""
        counts = self.plan.stream_counts(self._mix_floor())
        return {name: sub.published_steps - counts[name]
                for name, sub in self._subs.items()}

    def _mix_floor(self) -> int:
        return floor_to_data_step(self.global_step, self.topology.dp,
                                  self.data_topology.dp)

    # -- prefetch / lifecycle ----------------------------------------------------
    def start_prefetch(self) -> None:
        if self._elastic:
            # elastic reads reposition each sub-consumer's (step, slice) per
            # call; the dense-cursor prefetcher would race it
            return
        for sub in self._subs.values():
            sub.start_prefetch()

    def stop_prefetch(self) -> None:
        for sub in self._subs.values():
            sub.stop_prefetch()

    def close(self) -> None:
        for sub in self._subs.values():
            sub.close()

    @property
    def stats(self) -> Dict[str, object]:
        return {name: sub.stats for name, sub in self._subs.items()}
