"""MixedReader: one rank's multiplexed view over N named TGB streams.

Implements the facade ``BatchReader`` protocol. Each global step g is routed
to the stream the ``MixPlan`` schedules there; because per-stream steps are
dense and ordered, every underlying single-stream consumer just advances its
normal ``<V, S>`` cursor — the mixing layer adds no new read path, only
routing.

Exactly-once across streams: ``checkpoint()`` emits one composite token
carrying the mix position (the next global step) plus every stream's
``<V, S>`` cursor; ``restore()`` re-validates that the per-stream cursors are
exactly what the (weights, seed) schedule implies at that mix position, so a
token captured under different mix settings can never silently misalign the
streams.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional

from repro.core.objectstore import IOPool, Namespace
from repro.dataplane.tgb_backend import TGBBatchReader
from repro.dataplane.types import Batch, Checkpoint, Topology
from repro.streams.mixplan import MixPlan

__all__ = ["MixedReader"]


class MixedReader:
    """Facade reader multiplexing per-stream consumers via a MixPlan."""

    def __init__(self, plan: MixPlan, stream_namespaces: Mapping[str, Namespace],
                 topology: Topology, dp_rank: int, cp_rank: int, *,
                 prefetch_depth: int = 4, dense_read: bool = False,
                 verify_crc: bool = True,
                 io_pool: Optional[IOPool] = None,
                 resume: "Checkpoint | str | None" = None):
        self.plan = plan
        self.topology = topology
        self.dp_rank, self.cp_rank = dp_rank, cp_rank
        # one IOPool shared by every stream's consumer: N streams multiplex
        # one bounded in-flight request budget instead of N independent ones
        self.io_pool = io_pool or IOPool.default()
        self._subs: Dict[str, TGBBatchReader] = {
            name: TGBBatchReader(stream_namespaces[name], topology,
                                 dp_rank, cp_rank,
                                 prefetch_depth=prefetch_depth,
                                 dense_read=dense_read,
                                 verify_crc=verify_crc,
                                 io_pool=self.io_pool)
            for name in plan.names
        }
        self.global_step = 0  # next mixed step this reader will return
        ckpt = Checkpoint.coerce(resume)
        if ckpt is not None:
            self.restore(ckpt)

    # -- reads ----------------------------------------------------------------
    def next_batch(self, timeout_s: Optional[float] = None) -> Batch:
        name, stream_step = self.plan.position(self.global_step)
        sub = self._subs[name]
        if sub.consumer.step != stream_step:
            raise RuntimeError(
                f"stream {name!r} cursor {sub.consumer.step} diverged from "
                f"schedule step {stream_step} at global step "
                f"{self.global_step}; restore from a composite checkpoint")
        inner = sub.next_batch(timeout_s=timeout_s)
        batch = Batch.build(inner.payload, step=self.global_step,
                            version=inner.version, dp_rank=self.dp_rank,
                            cp_rank=self.cp_rank, topology=self.topology,
                            stream=name)
        self.global_step += 1
        return batch

    # -- cursor ----------------------------------------------------------------
    def checkpoint(self) -> Checkpoint:
        """Composite token: mix position + every stream's <V, S> cursor."""
        rows = []
        for name in self.plan.names:
            v, s = self._subs[name].consumer.cursor
            rows.append((name, v, s))
        return Checkpoint("tgb", version=-1, step=self.global_step,
                          streams=tuple(rows))

    def restore(self, ckpt: "Checkpoint | str") -> None:
        ckpt = Checkpoint.coerce(ckpt)
        if ckpt.backend != "tgb":
            raise ValueError(f"cannot restore a {ckpt.backend!r} checkpoint "
                             f"on a tgb mixed reader")
        if not ckpt.composite:
            raise ValueError("single-stream checkpoint cannot be restored on "
                             "a multi-stream reader")
        names = tuple(sorted(row[0] for row in ckpt.streams))
        if names != self.plan.names:
            raise ValueError(
                f"checkpoint streams {names} do not match session streams "
                f"{self.plan.names}")
        # the schedule is pure in (weights, seed, step): per-stream cursors
        # MUST equal the scheduled counts at the mix position, otherwise the
        # token was captured under different mix settings
        expect = self.plan.stream_counts(ckpt.step)
        for name, _v, s in ckpt.streams:
            if s != expect[name]:
                raise ValueError(
                    f"composite checkpoint is inconsistent with this "
                    f"session's MixPlan: stream {name!r} cursor {s} != "
                    f"scheduled count {expect[name]} at mix step {ckpt.step} "
                    f"(were weights/seed changed?)")
        for name, v, s in ckpt.streams:
            self._subs[name].consumer.restore_cursor(v, s)
        self.global_step = ckpt.step

    # -- progress probes --------------------------------------------------------
    def poll(self) -> bool:
        """Probe all streams for newly committed manifests."""
        advanced = False
        for sub in self._subs.values():
            advanced |= sub.poll()
        return advanced

    @property
    def published_steps(self) -> int:
        """Contiguous global steps currently servable: the first global step
        whose owning stream has not yet published the scheduled stream step.
        Anchored at this reader's cursor — everything below it was served."""
        published = {name: sub.published_steps
                     for name, sub in self._subs.items()}
        return self.plan.frontier(published, start=self.global_step)

    def stream_lag(self) -> Dict[str, int]:
        """Per-stream backlog: published-but-unconsumed stream steps."""
        return {name: sub.published_steps - sub.consumer.step
                for name, sub in self._subs.items()}

    # -- prefetch / lifecycle ----------------------------------------------------
    def start_prefetch(self) -> None:
        for sub in self._subs.values():
            sub.start_prefetch()

    def stop_prefetch(self) -> None:
        for sub in self._subs.values():
            sub.stop_prefetch()

    def close(self) -> None:
        for sub in self._subs.values():
            sub.close()

    @property
    def stats(self) -> Dict[str, object]:
        return {name: sub.stats for name, sub in self._subs.items()}
