"""One named TGB stream: an independent manifest chain under a per-stream
namespace prefix (``<run>/streams/<name>/...``).

A stream is structurally a complete single-stream BatchWeave run — its own
producers, DAC state, commit protocol, watermarks, trim marker, and reclaimer
— which is what lets every existing core client (Producer, Consumer,
Reclaimer) run unmodified underneath the mixing layer. Only the *watermarks*
written into a stream are special: they are mix-aware stream-step cursors
derived from composite checkpoints, so a stream only reclaims TGBs below the
lowest stream step any mixed reader can still revisit.
"""
from __future__ import annotations

from typing import Optional

from repro.core.lifecycle import Reclaimer, Watermark, write_watermark
from repro.core.manifest import DatasetView, ManifestStore, open_manifest_store
from repro.core.objectstore import Namespace

__all__ = ["Stream"]


class Stream:
    """Server-side handle on one named stream of a multi-stream run."""

    def __init__(self, parent_ns: Namespace, name: str, weight: float,
                 expected_ranks: int):
        self.name = name
        self.weight = weight
        self.ns = parent_ns.stream(name)
        self.expected_ranks = expected_ranks
        # shard-layout discovery: a sharded stream transparently yields the
        # merged read view; legacy streams get the plain single-chain store
        self._manifests = open_manifest_store(self.ns)
        self._view = DatasetView()
        self._reclaimer: Optional[Reclaimer] = None

    # -- producers -----------------------------------------------------------
    def manifests(self) -> ManifestStore:
        return self._manifests

    def manifest_view(self) -> DatasetView:
        """Latest committed view. Polls forward from the cached version (the
        same hint/base pattern as Consumer.poll), so repeated lag/frontier
        probes cost O(new versions), not O(history)."""
        latest = self._manifests.latest_version(hint=self._view.version)
        if latest > self._view.version:
            self._view = self._manifests.load_view(latest, base=self._view)
        return self._view

    @property
    def published_steps(self) -> int:
        """Stream steps currently committed (visible) in this stream."""
        return self.manifest_view().total_steps

    # -- mix-aware lifecycle ---------------------------------------------------
    def save_watermark(self, rank: int, version: int, stream_step: int) -> None:
        """Publish rank ``rank``'s mix-aware watermark for this stream: the
        (manifest version, stream step) below which this rank will never read
        again. Called with cursors taken from a composite checkpoint."""
        write_watermark(self.ns, rank, Watermark(version=version,
                                                 step=stream_step))

    def reclaimer(self) -> Reclaimer:
        if self._reclaimer is None:
            self._reclaimer = Reclaimer(self.ns,
                                        expected_ranks=self.expected_ranks)
        return self._reclaimer

    def reclaim_cycle(self) -> int:
        """One watermark-driven reclamation cycle; returns TGBs deleted so far
        for this stream."""
        r = self.reclaimer()
        r.run_cycle()
        return r.stats.tgbs_deleted

    # -- derived streams -------------------------------------------------------
    def derive_cursors(self):
        """The derive-cursor store of this stream (non-empty only when the
        stream is the output of a ``repro.graph`` DeriveWorker)."""
        from repro.graph.cursor import DeriveCursorStore
        return DeriveCursorStore(self.ns)

    def latest_derive_cursor(self):
        """Latest committed DeriveCursor, or None for a raw stream."""
        return self.derive_cursors().latest()

    @property
    def is_derived(self) -> bool:
        """True if any committed TGB of this stream carries provenance."""
        return bool(self.manifest_view().derived_tgbs())

    def __repr__(self) -> str:
        return f"Stream({self.name!r}, weight={self.weight:.3f})"
