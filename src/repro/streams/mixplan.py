"""Deterministic weighted mixing schedule (multi-stream data plane).

A ``MixPlan`` maps every global training step to ``(stream, stream_step)``
via smooth weighted round-robin (SRR): each step every stream accrues credit
proportional to its normalized weight, the richest stream is chosen, and the
winner pays back one full unit. The resulting interleave is *stride-like* —
over any window of N steps each stream is scheduled ``~N * w`` times with
bounded (O(1)) deviation, so no stream is starved and per-stream consumption
is as smooth as the weights allow.

Two properties the rest of the subsystem leans on:

  * **Pure function of (weights, seed, step).** No schedule object is ever
    stored: a restored reader (or a reclaimer on another machine) rebuilds the
    identical step -> (stream, stream_step) mapping from the session config
    alone. The seed perturbs the initial credits, giving different-but-equally
    -smooth interleavings per run.
  * **Per-stream steps are dense and ordered.** The k-th time a stream is
    scheduled it is assigned stream_step k, so every stream's substream is
    consumed strictly sequentially — exactly what the single-stream consumer
    cursor ``<V, S>`` supports.

Memory is O(n_streams + recent window), not O(steps): the SRR state rolls
forward (credits + per-stream counts), a bounded window of recent entries
serves the reader's near-cursor revisits, and cold queries far behind the
frontier (restore validation, test replays) recompute from step 0 — O(step)
time, zero retained state.
"""
from __future__ import annotations

import random
import threading
from typing import Dict, List, Mapping, Tuple

__all__ = ["MixPlan"]

# near-cursor entries kept for O(1) revisits; anything older is recomputed
_RECENT_WINDOW = 8192


class _Walker:
    """Rolling SRR state: O(n_streams) memory, one schedule step per advance."""

    __slots__ = ("w", "credits", "counts", "step")

    def __init__(self, w: List[float], init_credits: List[float]):
        self.w = w
        self.credits = list(init_credits)
        self.counts = [0] * len(w)
        self.step = 0  # next global step this walker will schedule

    def advance(self) -> Tuple[int, int]:
        """Schedule global step ``self.step``; returns (stream idx, stream_step)."""
        credits = self.credits
        for i, wi in enumerate(self.w):
            credits[i] += wi
        j = max(range(len(credits)), key=lambda i: (credits[i], -i))
        credits[j] -= 1.0  # weights are normalized: one unit per step
        sstep = self.counts[j]
        self.counts[j] += 1
        self.step += 1
        return j, sstep


class MixPlan:
    """Deterministic step -> (stream, stream_step) schedule."""

    def __init__(self, weights: Mapping[str, float], seed: int = 0):
        if not weights:
            raise ValueError("MixPlan needs at least one stream")
        for name, w in weights.items():
            if not name or not isinstance(name, str):
                raise ValueError(f"bad stream name {name!r}")
            if not (w > 0):
                raise ValueError(f"stream {name!r} weight must be > 0, got {w}")
        # sorted name order + a seeded RNG make the schedule a pure function
        # of (weights, seed) regardless of dict insertion order
        self.names: Tuple[str, ...] = tuple(sorted(weights))
        total = float(sum(weights[n] for n in self.names))
        self.weights: Dict[str, float] = {n: weights[n] / total
                                          for n in self.names}
        self.seed = seed
        rng = random.Random((seed, len(self.names), *self.names).__repr__())
        self._w = [self.weights[n] for n in self.names]
        # initial credit in [0, w_i): breaks ties and phase-shifts the
        # interleave per seed without disturbing long-run proportions
        self._init_credits = [rng.random() * wi for wi in self._w]
        self._head = _Walker(self._w, self._init_credits)
        self._recent: Dict[int, Tuple[int, int]] = {}  # step -> (idx, sstep)
        # dedicated monotone walker for stream_counts probes (reclaim/lag)
        self._counter = _Walker(self._w, self._init_credits)
        self._lock = threading.Lock()

    # -- schedule materialization -------------------------------------------
    def _advance_head_to(self, step: int) -> None:
        while self._head.step <= step:
            g = self._head.step
            self._recent[g] = self._head.advance()
            self._recent.pop(g - _RECENT_WINDOW, None)

    def _cold_entry(self, step: int) -> Tuple[int, int]:
        """Recompute one entry far behind the recent window from scratch."""
        w = _Walker(self._w, self._init_credits)
        for _ in range(step):
            w.advance()
        return w.advance()

    # -- queries -------------------------------------------------------------
    def position(self, step: int) -> Tuple[str, int]:
        """The (stream name, stream_step) serving global step ``step``.

        Amortized O(1) at or ahead of the frontier and within the recent
        window; O(step) recompute for cold queries far behind it."""
        if step < 0:
            raise ValueError(f"step must be >= 0, got {step}")
        with self._lock:
            entry = self._recent.get(step)
            if entry is None and step >= self._head.step:
                self._advance_head_to(step)
                entry = self._recent[step]
        if entry is None:
            entry = self._cold_entry(step)
        j, sstep = entry
        return self.names[j], sstep

    def schedule(self, n_steps: int) -> List[Tuple[str, int]]:
        """The first ``n_steps`` entries of the step -> (stream, stream_step)
        mapping (test/replay helper; recomputed, nothing retained)."""
        w = _Walker(self._w, self._init_credits)
        out = []
        for _ in range(max(0, n_steps)):
            j, sstep = w.advance()
            out.append((self.names[j], sstep))
        return out

    def stream_counts(self, upto_step: int) -> Dict[str, int]:
        """Per-stream scheduled-step counts over global steps [0, upto_step).

        ``stream_counts(G)[name]`` is exactly the stream_step cursor stream
        ``name`` must hold when the mixed reader's next global step is ``G`` —
        the invariant composite checkpoints are validated against, and the
        mix-aware low-watermark used for per-stream trimming. Amortized O(1)
        for monotone probes; O(upto_step) recompute for backward ones."""
        if upto_step <= 0:
            return dict.fromkeys(self.names, 0)
        with self._lock:
            if upto_step >= self._counter.step:
                while self._counter.step < upto_step:
                    self._counter.advance()
                counts = list(self._counter.counts)
            else:  # backward probe (rare: restore validation): fresh walk
                w = _Walker(self._w, self._init_credits)
                for _ in range(upto_step):
                    w.advance()
                counts = w.counts
        return {self.names[i]: counts[i] for i in range(len(self.names))}

    def frontier(self, published: Mapping[str, int], start: int = 0) -> int:
        """Largest global step G >= start such that every step in [start, G)
        is backed by a published stream step (``published[name]`` = stream
        steps currently visible). The mixed reader's contiguous-progress
        probe — callers pass their cursor as ``start`` (everything below it
        was already served) so the walk covers only new ground."""
        g = max(0, start)
        while True:
            name, sstep = self.position(g)
            if sstep >= published.get(name, 0):
                return g
            g += 1

    def __repr__(self) -> str:
        ws = ", ".join(f"{n}={self.weights[n]:.3f}" for n in self.names)
        return f"MixPlan({ws}, seed={self.seed})"
