"""MultiStreamSession: one training run owning N named TGB streams.

Opened through the facade::

    session = open_dataplane(store, topo, backend="tgb",
                             streams={"web": 0.7, "code": 0.3}, mix_seed=42,
                             namespace="runs/pretrain")
    with session.writer("w0", stream="web") as w: ...
    reader = session.reader(dp_rank=0, cp_rank=0)   # -> MixedReader

Each stream is an independent manifest chain under ``<run>/streams/<name>``;
producers attach to exactly one stream and are oblivious to the mixing layer.
The deterministic MixPlan (weights, seed) is the *only* cross-stream state,
and it is config, not data — nothing about the schedule is ever persisted.

Lifecycle is mix-aware: ``save_watermark`` splits a composite checkpoint into
per-stream ``(version, stream_step)`` watermarks, so each stream's reclaimer
computes its own W_global over exactly the steps mixed readers can still
revisit, and a stream never reclaims a TGB the mix still needs.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional

from repro.core.dac import CommitPolicy
from repro.core.objectstore import IOPool, Namespace, ObjectStore
from repro.core.resilience import wrap_store
from repro.dataplane._base import SessionBase
from repro.dataplane.tgb_backend import TGBWriter
from repro.dataplane.types import Checkpoint, Topology
from repro.streams.mixed_reader import MixedReader
from repro.streams.mixplan import MixPlan
from repro.streams.stream import Stream

__all__ = ["MultiStreamSession"]


class MultiStreamSession(SessionBase):
    """A handle on one run's multi-stream data plane (tgb transport)."""

    backend = "tgb"

    def __init__(self, store: ObjectStore, topology: Topology, *,
                 streams: Mapping[str, float], mix_seed: int = 0,
                 namespace: str = "runs/dataplane",
                 resume: "Checkpoint | str | None" = None,
                 expected_ranks: Optional[int] = None,
                 io_pool: Optional[IOPool] = None,
                 data_topology: Optional[Topology] = None,
                 resilience=None):
        if not isinstance(store, ObjectStore):
            raise TypeError(f"tgb backend needs an ObjectStore target, got "
                            f"{type(store).__name__}")
        # one shared resilience layer for every stream's clients (same
        # breaker/governor — the whole run backs off together)
        store = wrap_store(store, resilience)
        self.store = store
        self.topology = topology
        # the layout producers materialized (and keep materializing) at; if
        # not given it is discovered from the streams' manifests on first
        # reader/writer, so an elastically-resized session keeps the stream
        # layout uniform and remaps reads instead of rewriting data
        self._data_topology = data_topology
        self.ns = Namespace(store, namespace)
        self.plan = MixPlan(streams, seed=mix_seed)
        self.mix_seed = mix_seed
        self._expected_ranks = expected_ranks or topology.world
        self.streams: Dict[str, Stream] = {
            name: Stream(self.ns, name, self.plan.weights[name],
                         self._expected_ranks)
            for name in self.plan.names
        }
        self._io_pool = io_pool  # shared across every reader's streams
        self._resume = Checkpoint.coerce(resume)
        if self._resume is not None and not self._resume.composite:
            raise ValueError("multi-stream session needs a composite "
                             "checkpoint token (one carrying per-stream "
                             "cursors), got a single-stream token")
        self._readers: List[MixedReader] = []
        self._frontier = 0  # last known contiguous mix frontier (monotone)

    # -- clients -------------------------------------------------------------
    @property
    def stream_names(self):
        return self.plan.names

    @property
    def data_topology(self) -> Topology:
        """The materialized per-stream D x C layout. Discovered from the
        first stream manifest that lists a TGB; before any TGB exists (a
        fresh run) it is the consuming topology."""
        if self._data_topology is None:
            for s in self.streams.values():
                view = s.manifest_view()
                if view.tgbs:
                    t = view.tgbs[0]
                    if (t.dp, t.cp) != (self.topology.dp, self.topology.cp):
                        gb = self.topology.global_batch
                        if gb is not None:
                            gb = gb * t.dp // self.topology.dp
                        self._data_topology = Topology(
                            dp=t.dp, cp=t.cp, global_batch=gb,
                            seq_len=self.topology.seq_len)
                    break
            if self._data_topology is None:
                self._data_topology = self.topology
        return self._data_topology

    def writer(self, writer_id: str = "w0", *, stream: Optional[str] = None,
               policy: Optional[CommitPolicy] = None,
               max_lag: Optional[int] = None,
               pipeline_commits: bool = False,
               spill_limit: Optional[int] = None) -> TGBWriter:
        """A producer handle bound to one named stream."""
        if stream is None or stream not in self.streams:
            raise ValueError(
                f"multi-stream writer needs stream=<name>; available: "
                f"{', '.join(self.plan.names)} (got {stream!r})")
        return TGBWriter(self.streams[stream].ns, self.data_topology,
                         writer_id, policy=policy, max_lag=max_lag,
                         pipeline_commits=pipeline_commits,
                         io_pool=self._io_pool, spill_limit=spill_limit)

    def reader(self, dp_rank: int = 0, cp_rank: int = 0, *,
               prefetch_depth: int = 4, dense_read: bool = False,
               verify_crc: bool = True,
               resume: "Checkpoint | str | None" = None) -> MixedReader:
        r = MixedReader(self.plan,
                        {name: s.ns for name, s in self.streams.items()},
                        self.topology, dp_rank, cp_rank,
                        prefetch_depth=prefetch_depth, dense_read=dense_read,
                        verify_crc=verify_crc, io_pool=self._io_pool,
                        resume=resume if resume is not None else self._resume,
                        data_topology=self.data_topology)
        self._readers.append(r)
        return r

    # -- derived streams -------------------------------------------------------
    def derive_worker(self, graph, output: Optional[str] = None, *,
                      worker_id: str = "derive-0", window_steps: int = 4,
                      verify_crc: bool = True):
        """A ``DeriveWorker`` executing one chain of ``graph`` under this
        run's namespace. The graph's source streams are this session's
        streams (or other derived streams already materialized here); its
        output becomes an ordinary stream that can be listed in a future
        session's mix weights and read by any MixedReader."""
        from repro.graph.worker import DeriveWorker
        return DeriveWorker(self.ns, graph, self.data_topology, output,
                            worker_id=worker_id, window_steps=window_steps,
                            verify_crc=verify_crc, io_pool=self._io_pool)

    # -- mix-aware lifecycle ---------------------------------------------------
    def save_watermark(self, rank: int, ckpt: "Checkpoint | str") -> None:
        """Split a composite checkpoint into per-stream mix-aware watermarks."""
        ckpt = Checkpoint.coerce(ckpt)
        if not ckpt.composite:
            raise ValueError("multi-stream save_watermark needs a composite "
                             "checkpoint (reader.checkpoint() of a "
                             "MixedReader)")
        for name, version, stream_step in ckpt.streams:
            self.streams[name].save_watermark(rank, version, stream_step)

    def reclaim(self) -> int:
        """One reclamation cycle per stream; returns total TGBs deleted so
        far. Each stream trims only below its own mix-aware W_global."""
        return sum(s.reclaim_cycle() for s in self.streams.values())

    @property
    def reclaim_stats(self) -> Dict[str, object]:
        return {name: s.reclaimer().stats for name, s in self.streams.items()}

    # -- introspection ----------------------------------------------------------
    def manifest_view(self, stream: str):
        """Latest committed DatasetView of one stream."""
        return self.streams[stream].manifest_view()

    def published_steps(self) -> int:
        """Contiguous global (mixed) steps currently servable. Published
        counts only grow, so the probe resumes from the last frontier."""
        published = {name: s.published_steps
                     for name, s in self.streams.items()}
        self._frontier = self.plan.frontier(published, start=self._frontier)
        return self._frontier

    def stream_lag(self, upto_global_step: Optional[int] = None
                   ) -> Dict[str, int]:
        """Per-stream published-ahead backlog relative to the mix frontier
        (``published stream steps - steps the mix has scheduled``)."""
        counts = self.plan.stream_counts(
            self.published_steps() if upto_global_step is None
            else upto_global_step)
        return {name: s.published_steps - counts[name]
                for name, s in self.streams.items()}

    def close(self) -> None:
        for r in self._readers:
            r.close()
        self._readers.clear()
