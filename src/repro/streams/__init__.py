"""Multi-stream data plane: named TGB streams with deterministic weighted
mixing.

Modern LFM training draws from many corpora with per-source weights (web,
code, domain SFT, ...). This package composes BatchWeave's single-stream TGB
semantics across sources:

  ``MixPlan``            deterministic weighted interleave — a pure function
                         of (weights, seed, step); no schedule is stored.
  ``Stream``             one named stream = an independent manifest chain
                         under ``<run>/streams/<name>/...``.
  ``MixedReader``        the facade ``BatchReader`` multiplexing per-stream
                         consumers; composite exactly-once checkpoints.
  ``MultiStreamSession`` the session facade: per-stream writers, mixed
                         readers, mix-aware per-stream lifecycle.

Entry point: ``open_dataplane(store, topo, backend="tgb",
streams={"web": 0.7, "code": 0.3}, mix_seed=...)``.
"""
from repro.streams.mixed_reader import MixedReader
from repro.streams.mixplan import MixPlan
from repro.streams.session import MultiStreamSession
from repro.streams.stream import Stream

__all__ = ["MixPlan", "MixedReader", "MultiStreamSession", "Stream"]
