"""Read-only run introspection (the ``batchweave inspect`` engine).

Builds a plain-dict summary of a run namespace straight from storage:
manifest chain shape, per-producer durable state, watermarks, the trim
marker, derivation state (derive cursors + per-TGB provenance on derived
streams), and (recursively) every stream of a multi-stream run. The dict is
stable and JSON-serializable so scripts can consume ``--json`` output.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.lifecycle import read_trim_marker, read_watermarks
from repro.core.manifest import (MANIFEST_FORMAT_FLAT, ManifestStore,
                                 ShardedManifestStore, read_shard_config)
from repro.core.objectstore import Namespace, NoSuchKey
from repro.ops.fsck import _manifest_versions, list_streams

__all__ = ["inspect_run"]


def _inspect_runmanifest(ns: Namespace) -> Optional[Dict]:
    """Summary of the run's aligned-checkpoint chain (None when the run has
    no RunManifest — a bare data-plane namespace)."""
    from repro.run.manifest import RunManifestError, RunManifestStore

    runs = RunManifestStore(ns)
    seqs = runs.seqs()
    if not seqs:
        return None
    out: Dict = {"entries": len(seqs), "oldest": seqs[0], "latest": seqs[-1]}
    try:
        rm = runs.read(seqs[-1])
        ck = rm.data_checkpoint()
        out["aligned"] = {
            "step": rm.step,
            "model_key": rm.model_key,
            "topology": list(rm.topology),
            "data_dp": rm.data_dp,
            "data_step": rm.aligned_data_step(),
            "cursor_version": ck.version,
            "streams": ({name: {"version": v, "step": s}
                         for name, v, s in ck.streams}
                        if ck.composite else None),
        }
    except ValueError as e:  # RunManifestError or a corrupt bound token:
        out["error"] = str(e)  # report it — fsck names the exact issue
    return out


def _inspect_derive(ns: Namespace, view) -> Optional[Dict]:
    """Derivation summary of one stream (None for raw streams): the derive
    cursor chain plus every derived TGB's provenance record."""
    from repro.graph.cursor import DeriveCursorError, DeriveCursorStore

    cur_store = DeriveCursorStore(ns)
    seqs = cur_store.seqs()
    derived = view.derived_tgbs() if view is not None else []
    if not seqs and not derived:
        return None
    out: Dict = {"cursors": len(seqs)}
    if seqs:
        try:
            dc = cur_store.read(seqs[-1])
            out["cursor"] = {"seq": dc.seq, "src_step": dc.src_step,
                             "out_seq": dc.out_seq, "graph": dc.graph,
                             "op": dc.op, "worker": dc.worker_id}
        except DeriveCursorError as e:
            out["cursor_error"] = str(e)
    out["derived_tgbs"] = [
        {"step": step, "tgb_id": t.tgb_id,
         "src_stream": t.provenance.get("src_stream"),
         "src": list(t.provenance.get("src", [])),
         "op": t.provenance.get("op"),
         "params": t.provenance.get("params"),
         "graph": t.provenance.get("graph"),
         "out_index": t.provenance.get("k")}
        for step, t in derived
    ]
    return out


def inspect_run(ns: Namespace, recurse_streams: bool = True) -> Dict:
    """Summarize one run namespace from storage alone (no client state)."""
    store = ns.store
    versions = _manifest_versions(ns)
    out: Dict = {
        "namespace": ns.prefix,
        "manifests": {
            "retained": len(versions),
            "oldest": versions[0] if versions else None,
            "latest": versions[-1] if versions else None,
        },
        "producers": {},
        "watermarks": {},
        "trim": None,
        "tgb_objects": len(store.list(ns.key("tgb"))),
    }
    view = None
    try:
        n_shards = read_shard_config(ns)
    except Exception:
        n_shards = None
    if n_shards is not None and n_shards > 1:
        m = ShardedManifestStore(ns, n_shards)
        latest = m.latest_version()
        mv = m.load_view(latest) if latest >= 0 else None
        shard_rows = []
        for k, shard in enumerate(m.shards):
            head = shard.latest_version(hint=-1)
            sv = shard.load_view(head) if head >= 0 else None
            shard_rows.append({
                "shard": k,
                "head_version": head,
                "base_step": sv.base_step if sv is not None else 0,
                "live_entries": len(sv.tgbs) if sv is not None else 0,
                "producers": sorted(sv.producers) if sv is not None else [],
            })
        seg_seqs = m.segments.seqs()
        out["manifests"]["sharded"] = {
            "n_shards": n_shards,
            "merged_version": latest,
            "frontier": mv.frontier if mv is not None else -1,
            "shards": shard_rows,
            "segments": {
                "retained": len(seg_seqs),
                "oldest": seg_seqs[0] if seg_seqs else None,
                "latest": seg_seqs[-1] if seg_seqs else None,
                "folded_steps": (m.segments.read(seg_seqs[-1]).end_step
                                 if seg_seqs else 0),
            },
        }
        if mv is not None:
            view = mv
            out["view"] = {
                "version": mv.version,
                "base_step": mv.base_step,
                "total_steps": mv.total_steps,
                "live_tgbs": len(mv.tgbs),
                "live_bytes": sum(t.size_bytes for t in mv.tgbs),
            }
            out["producers"] = {
                pid: {"committed_offset": st.committed_offset,
                      "last_commit_version": st.last_commit_version,
                      "epoch": st.epoch}
                for pid, st in sorted(mv.producers.items())
            }
    elif versions:
        manifests = ManifestStore(ns)
        doc = manifests.read_doc(versions[-1])
        out["manifests"]["format"] = doc.get("format", MANIFEST_FORMAT_FLAT)
        try:
            out["manifests"]["bytes"] = store.head(
                ns.manifest_key(versions[-1]))
        except (KeyError, NoSuchKey):
            out["manifests"]["bytes"] = None
        view = manifests.load_view(versions[-1])
        out["view"] = {
            "version": view.version,
            "base_step": view.base_step,
            "total_steps": view.total_steps,
            "live_tgbs": len(view.tgbs),
            "live_bytes": sum(t.size_bytes for t in view.tgbs),
        }
        out["producers"] = {
            pid: {"committed_offset": st.committed_offset,
                  "last_commit_version": st.last_commit_version,
                  "epoch": st.epoch}
            for pid, st in sorted(view.producers.items())
        }
    for rank, wm in sorted(read_watermarks(ns).items()):
        out["watermarks"][str(rank)] = {"version": wm.version, "step": wm.step}
    trim = read_trim_marker(ns)
    if trim is not None:
        out["trim"] = {"safe_step": trim[0], "safe_version": trim[1]}
    derive = _inspect_derive(ns, view)
    if derive is not None:
        out["derive"] = derive
    out["runmanifest"] = _inspect_runmanifest(ns)
    if recurse_streams:
        streams = {name: inspect_run(ns.stream(name), recurse_streams=False)
                   for name in list_streams(ns)}
        if streams:
            out["streams"] = streams
    return out
