"""``python -m repro.ops`` — the ``batchweave`` ops CLI."""
import sys

from repro.ops.cli import main

if __name__ == "__main__":
    sys.exit(main())
