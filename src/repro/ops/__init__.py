"""``repro.ops`` — the ``batchweave`` operator toolkit.

Programmatic API::

    from repro.core import Namespace
    from repro.ops import fsck, inspect_run

    report = fsck(Namespace(store, "runs/myjob"), repair=False)
    assert report.clean, report.summary()

CLI (filesystem-backed stores)::

    python -m repro.ops --root /data/bw --namespace runs/myjob inspect
    python -m repro.ops --root /data/bw -n runs/myjob fsck --repair
    python -m repro.ops --root /data/bw -n runs/myjob trim --ranks 4

See ``docs/OPERATIONS.md`` for the full runbook.
"""
from repro.ops.cli import build_parser, main
from repro.ops.fsck import FsckIssue, FsckReport, fsck, list_streams
from repro.ops.inspect import inspect_run

__all__ = ["FsckIssue", "FsckReport", "build_parser", "fsck", "inspect_run",
           "list_streams", "main"]
