"""Storage-native integrity checking (the ``batchweave fsck`` engine).

Everything here operates purely through the ``ObjectStore`` interface — no
side channel, no producer/consumer state — per the paper's storage-native
recovery design: the object store *is* the system of record, so any operator
tool (or replacement process) can audit a run from the namespace alone.

Checks performed per namespace (and recursively per stream):

  * **manifest chain** — retained versions must be contiguous (the reclaimer
    deletes only a prefix); every doc must decode; a delta chain must resolve
    parent-by-parent back to a snapshot or genesis. Violations are "torn
    chain" errors.
  * **torn commits** — every TGB the latest view references must exist with
    exactly the byte size the manifest recorded.
  * **orphans** — objects under ``tgb/`` that no retained manifest reaches.
    Offsets at or below the producer's committed offset are superseded
    duplicates from crashed incarnations (or trim leftovers): safe to delete,
    and ``repair`` does. Offsets above it may belong to a *live* producer's
    uncommitted pending set, so they are reported but never touched.
  * **trim-vs-checkpoint skew** — the trim marker must never pass the lowest
    checkpoint watermark (else a restoring rank could find its steps
    reclaimed), the latest view's ``base_step`` must not exceed it either,
    and every watermark's manifest version must still be retained.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import msgpack

from repro.core.lifecycle import read_trim_marker, read_watermarks
from repro.core.manifest import (MANIFEST_FORMAT_FLAT, DatasetView,
                                 ManifestStore)
from repro.core.objectstore import Namespace, NoSuchKey

__all__ = ["FsckIssue", "FsckReport", "fsck", "list_streams"]


@dataclass(frozen=True)
class FsckIssue:
    severity: str  # "error" | "warn"
    kind: str      # e.g. "torn-manifest-chain", "missing-tgb", "orphan-tgb"
    key: str       # object key (or logical subject) the issue is about
    detail: str

    def __str__(self) -> str:
        return f"[{self.severity}] {self.kind}: {self.key} — {self.detail}"


@dataclass
class FsckReport:
    namespace: str
    issues: List[FsckIssue] = field(default_factory=list)
    checked_manifests: int = 0
    checked_tgbs: int = 0
    orphans: List[str] = field(default_factory=list)   # safe-to-delete keys
    pending: List[str] = field(default_factory=list)   # possibly-live keys
    repaired: List[str] = field(default_factory=list)  # deleted by repair
    streams: Dict[str, "FsckReport"] = field(default_factory=dict)

    @property
    def clean(self) -> bool:
        """No errors and no reclaimable orphans, here or in any stream."""
        if any(i.severity == "error" for i in self.issues) or self.orphans:
            return False
        return all(r.clean for r in self.streams.values())

    def all_issues(self) -> List[FsckIssue]:
        out = list(self.issues)
        for r in self.streams.values():
            out.extend(r.all_issues())
        return out

    def summary(self) -> str:
        n_err = sum(1 for i in self.all_issues() if i.severity == "error")
        n_warn = sum(1 for i in self.all_issues() if i.severity == "warn")
        orphans = len(self.orphans) + sum(len(r.orphans)
                                          for r in self.streams.values())
        state = "clean" if self.clean else "NOT CLEAN"
        return (f"fsck {self.namespace}: {state} "
                f"({self.checked_manifests} manifests, "
                f"{self.checked_tgbs} tgbs, {n_err} errors, {n_warn} warnings, "
                f"{orphans} orphans, {len(self.repaired)} repaired)")


def list_streams(ns: Namespace) -> List[str]:
    """Names of child streams under ``<prefix>/streams/`` (storage-derived)."""
    prefix = ns.key("streams") + "/"
    names = set()
    for key in ns.store.list(prefix):
        rest = key[len(prefix):]
        if "/" in rest:
            names.add(rest.split("/", 1)[0])
    return sorted(names)


def _manifest_versions(ns: Namespace) -> List[int]:
    out = []
    for key in ns.store.list(ns.key("manifest")):
        try:
            out.append(int(key.rsplit("/", 1)[-1].split(".")[0]))
        except ValueError:
            pass
    return sorted(out)


def _parse_tgb_key(ns: Namespace, key: str) -> Optional[Tuple[str, int]]:
    """``<prefix>/tgb/<producer_id>/<offset>-<token>.tgb`` -> (pid, offset)."""
    prefix = ns.key("tgb") + "/"
    if not key.startswith(prefix):
        return None
    rest = key[len(prefix):]
    try:
        pid, fname = rest.rsplit("/", 1)
        offset = int(fname.split("-", 1)[0])
    except ValueError:
        return None
    return pid, offset


def _check_chain(ns: Namespace, versions: List[int],
                 report: FsckReport) -> Optional[DatasetView]:
    """Validate the manifest chain; return the latest view if loadable."""
    store = ns.store
    for prev, cur in zip(versions, versions[1:]):
        if cur != prev + 1:
            report.issues.append(FsckIssue(
                "error", "torn-manifest-chain", ns.manifest_key(prev + 1),
                f"retained versions jump {prev} -> {cur}: intermediate "
                f"manifests are missing"))
    docs = {}
    for v in versions:
        try:
            docs[v] = msgpack.unpackb(store.get(ns.manifest_key(v)), raw=False,
                                      strict_map_key=False)
            report.checked_manifests += 1
        except (KeyError, NoSuchKey):
            report.issues.append(FsckIssue(
                "error", "unreadable-manifest", ns.manifest_key(v),
                "listed but not readable"))
        except Exception as e:  # undecodable payload = torn commit
            report.issues.append(FsckIssue(
                "error", "corrupt-manifest", ns.manifest_key(v),
                f"cannot decode: {type(e).__name__}: {e}"))
    if not versions or versions[-1] not in docs:
        return None
    # delta chains must resolve back to a snapshot / genesis / retained parent
    head = docs[versions[-1]]
    seen = set()
    while head.get("format", MANIFEST_FORMAT_FLAT) != MANIFEST_FORMAT_FLAT \
            and "snapshot_tgbs" not in head:
        parent = head.get("parent_version", -1)
        if parent < 0:
            break
        if parent in seen:
            report.issues.append(FsckIssue(
                "error", "torn-manifest-chain", ns.manifest_key(parent),
                "delta parent cycle"))
            return None
        seen.add(parent)
        if parent not in docs:
            report.issues.append(FsckIssue(
                "error", "torn-manifest-chain", ns.manifest_key(parent),
                f"delta manifest v{head.get('version')} needs parent "
                f"v{parent}, which is missing"))
            return None
        head = docs[parent]
    try:
        return ManifestStore(ns).load_view(versions[-1])
    except Exception as e:
        report.issues.append(FsckIssue(
            "error", "torn-manifest-chain", ns.manifest_key(versions[-1]),
            f"latest view does not reconstruct: {type(e).__name__}: {e}"))
        return None


def _check_tgbs(ns: Namespace, view: Optional[DatasetView],
                report: FsckReport) -> None:
    store = ns.store
    trim = read_trim_marker(ns)
    safe_step = trim[0] if trim is not None else 0
    referenced = set()
    if view is not None:
        for i, t in enumerate(view.tgbs):
            referenced.add(t.object_key)
            report.checked_tgbs += 1
            step = view.base_step + i
            try:
                size = store.head(t.object_key)
            except (KeyError, NoSuchKey):
                if step < safe_step:
                    # legitimately reclaimed: physically deleted below the
                    # trim marker, still listed until producers' next
                    # logical trim advances base_step
                    continue
                report.issues.append(FsckIssue(
                    "error", "missing-tgb", t.object_key,
                    f"step {step} referenced by manifest v{view.version} "
                    f"(tgb_id={t.tgb_id}) but absent from the store"))
                continue
            if size != t.size_bytes:
                report.issues.append(FsckIssue(
                    "error", "tgb-size-mismatch", t.object_key,
                    f"manifest records {t.size_bytes} B, object is {size} B "
                    f"(torn commit)"))
    for key in store.list(ns.key("tgb")):
        if key in referenced:
            continue
        parsed = _parse_tgb_key(ns, key)
        if parsed is None:
            report.orphans.append(key)
            report.issues.append(FsckIssue(
                "warn", "orphan-tgb", key, "unparseable key, unreferenced"))
            continue
        pid, offset = parsed
        committed = view.producer_offset(pid) if view is not None else -1
        if offset <= committed:
            report.orphans.append(key)
            report.issues.append(FsckIssue(
                "warn", "orphan-tgb", key,
                f"producer {pid!r} committed through offset {committed} via "
                f"other objects; this one is superseded (safe to delete)"))
        else:
            report.pending.append(key)
            report.issues.append(FsckIssue(
                "warn", "pending-tgb", key,
                f"offset {offset} > committed {committed}: uncommitted — "
                f"either a live producer's pending TGB or a crashed "
                f"incarnation's leftover (not touched)"))


def _check_trim_skew(ns: Namespace, view: Optional[DatasetView],
                     versions: List[int], report: FsckReport) -> None:
    wms = read_watermarks(ns)
    trim = read_trim_marker(ns)
    if wms:
        min_step = min(w.step for w in wms.values())
        min_version = min(w.version for w in wms.values())
        if trim is not None:
            safe_step, safe_version = trim
            if safe_step > min_step:
                report.issues.append(FsckIssue(
                    "error", "trim-skew", ns.trim_key(),
                    f"trim marker safe_step={safe_step} passed the lowest "
                    f"checkpoint watermark step {min_step}: a restoring rank "
                    f"would find its batches reclaimed"))
            if safe_version > min_version:
                report.issues.append(FsckIssue(
                    "error", "trim-skew", ns.trim_key(),
                    f"trim marker safe_version={safe_version} passed the "
                    f"lowest watermark version {min_version}"))
        if view is not None and view.base_step > min_step:
            report.issues.append(FsckIssue(
                "error", "trim-skew", ns.manifest_key(view.version),
                f"latest manifest base_step={view.base_step} passed the "
                f"lowest watermark step {min_step}"))
        if versions:
            lowest_retained = versions[0]
            for rank, wm in sorted(wms.items()):
                if wm.version >= 0 and wm.version < lowest_retained:
                    report.issues.append(FsckIssue(
                        "error", "watermark-unreadable",
                        ns.watermark_key(rank),
                        f"rank {rank} checkpointed at manifest v{wm.version} "
                        f"but the oldest retained version is "
                        f"v{lowest_retained}: that checkpoint cannot "
                        f"restore"))
    elif trim is not None and trim[0] > 0:
        report.issues.append(FsckIssue(
            "warn", "trim-without-watermarks", ns.trim_key(),
            f"trim marker at safe_step={trim[0]} but no watermarks exist"))


def fsck(ns: Namespace, repair: bool = False,
         recurse_streams: bool = True) -> FsckReport:
    """Audit one run namespace through the storage layer alone.

    ``repair=True`` deletes the *safely* orphaned TGB objects (superseded
    duplicates below their producer's committed offset) — never pending ones,
    never manifests. Returns the full :class:`FsckReport`.
    """
    report = FsckReport(namespace=ns.prefix)
    versions = _manifest_versions(ns)
    view = _check_chain(ns, versions, report)
    _check_tgbs(ns, view, report)
    _check_trim_skew(ns, view, versions, report)
    if repair and report.orphans:
        for key in list(report.orphans):
            ns.store.delete(key)
            report.repaired.append(key)
        report.orphans.clear()
    if recurse_streams:
        for name in list_streams(ns):
            report.streams[name] = fsck(ns.stream(name), repair=repair,
                                        recurse_streams=False)
    return report
