"""Storage-native integrity checking (the ``batchweave fsck`` engine).

Everything here operates purely through the ``ObjectStore`` interface — no
side channel, no producer/consumer state — per the paper's storage-native
recovery design: the object store *is* the system of record, so any operator
tool (or replacement process) can audit a run from the namespace alone.

Checks performed per namespace (and recursively per stream):

  * **manifest chain** — retained versions must be contiguous (the reclaimer
    deletes only a prefix); every doc must decode; a delta chain must resolve
    parent-by-parent back to a snapshot or genesis. Violations are "torn
    chain" errors.
  * **torn commits** — every TGB the latest view references must exist with
    exactly the byte size the manifest recorded.
  * **orphans** — objects under ``tgb/`` that no retained manifest reaches.
    Offsets at or below the producer's committed offset are superseded
    duplicates from crashed incarnations (or trim leftovers): safe to delete,
    and ``repair`` does. Offsets above it may belong to a *live* producer's
    uncommitted pending set, so they are reported but never touched.
  * **trim-vs-checkpoint skew** — the trim marker must never pass the lowest
    checkpoint watermark (else a restoring rank could find its steps
    reclaimed), the latest view's ``base_step`` must not exceed it either,
    and every watermark's manifest version must still be retained.
  * **derived streams** — on streams produced by ``repro.graph``: the
    derive-cursor chain must be contiguous, decodable, non-regressive, and
    never ahead of the manifest; derived TGBs whose provenance cites source
    TGBs the source manifest no longer resolves are flagged
    "provenance-dangling"; derived outputs above the committed cursor are
    reclassified as safe orphans (a restarted worker regenerates them
    content-addressed).
  * **RunManifest alignment** — on runs with a RunManifest: the entry chain
    must be contiguous and decodable; the latest entry's model checkpoint
    must exist intact (MANIFEST + every leaf at its recorded size); its data
    cursor must decode and still be restorable (manifest version retained,
    trim marker at or below the aligned step — per stream on multi-stream
    runs); and model uploads no entry ever named (a trainer killed between
    upload and commit) surface as safe orphans once a later entry
    supersedes them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import msgpack
import numpy as np

from repro.core.lifecycle import read_trim_marker, read_watermarks
from repro.core.manifest import (MANIFEST_FORMAT_FLAT, DatasetView,
                                 ManifestStore, ShardedManifestStore,
                                 read_shard_config)
from repro.core.objectstore import Namespace, NoSuchKey
from repro.dataplane.types import Checkpoint
from repro.run.manifest import RunManifestError, RunManifestStore

__all__ = ["FsckIssue", "FsckReport", "fsck", "list_streams"]


@dataclass(frozen=True)
class FsckIssue:
    severity: str  # "error" | "warn"
    kind: str      # e.g. "torn-manifest-chain", "missing-tgb", "orphan-tgb"
    key: str       # object key (or logical subject) the issue is about
    detail: str

    def __str__(self) -> str:
        return f"[{self.severity}] {self.kind}: {self.key} — {self.detail}"


@dataclass
class FsckReport:
    namespace: str
    issues: List[FsckIssue] = field(default_factory=list)
    checked_manifests: int = 0
    checked_tgbs: int = 0
    orphans: List[str] = field(default_factory=list)   # safe-to-delete keys
    pending: List[str] = field(default_factory=list)   # possibly-live keys
    repaired: List[str] = field(default_factory=list)  # deleted by repair
    streams: Dict[str, "FsckReport"] = field(default_factory=dict)

    @property
    def clean(self) -> bool:
        """No errors and no reclaimable orphans, here or in any stream."""
        if any(i.severity == "error" for i in self.issues) or self.orphans:
            return False
        return all(r.clean for r in self.streams.values())

    def all_issues(self) -> List[FsckIssue]:
        out = list(self.issues)
        for r in self.streams.values():
            out.extend(r.all_issues())
        return out

    def summary(self) -> str:
        n_err = sum(1 for i in self.all_issues() if i.severity == "error")
        n_warn = sum(1 for i in self.all_issues() if i.severity == "warn")
        orphans = len(self.orphans) + sum(len(r.orphans)
                                          for r in self.streams.values())
        state = "clean" if self.clean else "NOT CLEAN"
        return (f"fsck {self.namespace}: {state} "
                f"({self.checked_manifests} manifests, "
                f"{self.checked_tgbs} tgbs, {n_err} errors, {n_warn} warnings, "
                f"{orphans} orphans, {len(self.repaired)} repaired)")


def list_streams(ns: Namespace) -> List[str]:
    """Names of child streams under ``<prefix>/streams/`` (storage-derived)."""
    prefix = ns.key("streams") + "/"
    names = set()
    for key in ns.store.list(prefix):
        rest = key[len(prefix):]
        if "/" in rest:
            names.add(rest.split("/", 1)[0])
    return sorted(names)


def _manifest_versions(ns: Namespace, chain: str = "manifest") -> List[int]:
    """Retained versions of ONE chain, by direct-child listing: a prefix list
    of ``manifest/`` on a sharded run also matches shard subchains, compacted
    segments, and ``shards.cfg`` — none of which are this chain's versions."""
    prefix = ns.key(chain) + "/"
    out = []
    for key in ns.store.list(prefix):
        rest = key[len(prefix):]
        if "/" in rest or not rest.endswith(".manifest"):
            continue
        stem = rest[: -len(".manifest")]
        if stem.isdigit():
            out.append(int(stem))
    return sorted(out)


def _chain_key(ns: Namespace, chain: str, version: int) -> str:
    return ns.key(chain, f"{version:08d}.manifest")


def _parse_tgb_key(ns: Namespace, key: str) -> Optional[Tuple[str, int]]:
    """``<prefix>/tgb/<producer_id>/<offset>-<token>.tgb`` -> (pid, offset)."""
    prefix = ns.key("tgb") + "/"
    if not key.startswith(prefix):
        return None
    rest = key[len(prefix):]
    try:
        pid, fname = rest.rsplit("/", 1)
        offset = int(fname.split("-", 1)[0])
    except ValueError:
        return None
    return pid, offset


def _check_chain(ns: Namespace, versions: List[int], report: FsckReport,
                 chain: str = "manifest") -> Optional[DatasetView]:
    """Validate one manifest chain; return the latest view if loadable."""
    store = ns.store
    for prev, cur in zip(versions, versions[1:]):
        if cur != prev + 1:
            report.issues.append(FsckIssue(
                "error", "torn-manifest-chain", _chain_key(ns, chain, prev + 1),
                f"retained versions jump {prev} -> {cur}: intermediate "
                f"manifests are missing"))
    docs = {}
    for v in versions:
        try:
            docs[v] = msgpack.unpackb(store.get(_chain_key(ns, chain, v)),
                                      raw=False, strict_map_key=False)
            report.checked_manifests += 1
        except (KeyError, NoSuchKey):
            report.issues.append(FsckIssue(
                "error", "unreadable-manifest", _chain_key(ns, chain, v),
                "listed but not readable"))
        except Exception as e:  # undecodable payload = torn commit
            report.issues.append(FsckIssue(
                "error", "corrupt-manifest", _chain_key(ns, chain, v),
                f"cannot decode: {type(e).__name__}: {e}"))
    if not versions or versions[-1] not in docs:
        return None
    # delta chains must resolve back to a snapshot / genesis / retained parent
    head = docs[versions[-1]]
    seen = set()
    while head.get("format", MANIFEST_FORMAT_FLAT) != MANIFEST_FORMAT_FLAT \
            and "snapshot_tgbs" not in head:
        parent = head.get("parent_version", -1)
        if parent < 0:
            break
        if parent in seen:
            report.issues.append(FsckIssue(
                "error", "torn-manifest-chain", _chain_key(ns, chain, parent),
                "delta parent cycle"))
            return None
        seen.add(parent)
        if parent not in docs:
            report.issues.append(FsckIssue(
                "error", "torn-manifest-chain", _chain_key(ns, chain, parent),
                f"delta manifest v{head.get('version')} needs parent "
                f"v{parent}, which is missing"))
            return None
        head = docs[parent]
    try:
        return ManifestStore(ns, chain=chain).load_view(versions[-1])
    except Exception as e:
        report.issues.append(FsckIssue(
            "error", "torn-manifest-chain",
            _chain_key(ns, chain, versions[-1]),
            f"latest view does not reconstruct: {type(e).__name__}: {e}"))
        return None


def _check_sharded(ns: Namespace, n_shards: int,
                   report: FsckReport) -> Optional[DatasetView]:
    """Sharded-run audits: every shard chain (torn/corrupt/decodable), the
    compact-segment chain (sequence gaps, base/end continuity), compaction
    orphans (a shard base trimmed beyond the folded count is lost data; a
    base lagging the fold is a repairable compactor crash window), and the
    merged view's globally-ordered step sequence (duplicate TGBs, regressed
    per-producer sequences, committed offsets behind observed entries).
    Returns the merged view, or None if it does not reconstruct."""
    shard_views: List[Optional[DatasetView]] = []
    for k in range(n_shards):
        chain = f"manifest/shard-{k}"
        versions = _manifest_versions(ns, chain)
        shard_views.append(_check_chain(ns, versions, report, chain=chain))
    m = ShardedManifestStore(ns, n_shards)
    seqs = m.segments.seqs()
    for prev, cur in zip(seqs, seqs[1:]):
        if cur != prev + 1:
            report.issues.append(FsckIssue(
                "error", "torn-segment-chain", m.segments.seg_key(prev + 1),
                f"compact segment sequence jumps {prev} -> {cur}"))
    prev_end: Optional[int] = None
    latest_folds: Optional[List[int]] = None
    for seq in seqs:
        skey = m.segments.seg_key(seq)
        try:
            seg = m.segments.read(seq)
            report.checked_manifests += 1
        except Exception as e:
            report.issues.append(FsckIssue(
                "error", "corrupt-segment", skey,
                f"cannot decode: {type(e).__name__}: {e}"))
            prev_end = None
            continue
        if prev_end is not None and seg.base_step != prev_end:
            report.issues.append(FsckIssue(
                "error", "torn-segment-chain", skey,
                f"segment base_step {seg.base_step} != previous segment end "
                f"{prev_end}: folded history has a gap or overlap"))
        prev_end = seg.end_step
        latest_folds = list(seg.folds)
    if latest_folds is not None:
        for k, v in enumerate(shard_views):
            if v is None:
                continue
            if v.base_step > latest_folds[k]:
                report.issues.append(FsckIssue(
                    "error", "compaction-orphan",
                    _chain_key(ns, f"manifest/shard-{k}", v.version),
                    f"shard {k} trimmed its base to {v.base_step} but only "
                    f"{latest_folds[k]} of its entries are folded into "
                    f"segments: {v.base_step - latest_folds[k]} entries are "
                    f"unreachable"))
            elif v.base_step < latest_folds[k]:
                report.issues.append(FsckIssue(
                    "warn", "compaction-lagging-trim",
                    _chain_key(ns, f"manifest/shard-{k}", v.version),
                    f"shard {k} base {v.base_step} lags its folded count "
                    f"{latest_folds[k]} (compactor crash window; readers "
                    f"deduplicate, the next compactor cycle repairs)"))
    try:
        mv = m.load_view(m.latest_version())
    except Exception as e:
        report.issues.append(FsckIssue(
            "error", "merge-view-unreconstructable", ns.key("manifest"),
            f"merged shard view does not reconstruct: "
            f"{type(e).__name__}: {e}"))
        return None
    seen_ids: Dict[str, int] = {}
    last_seq: Dict[str, int] = {}
    for i, t in enumerate(mv.tgbs):
        step = mv.base_step + i
        if t.tgb_id in seen_ids:
            report.issues.append(FsckIssue(
                "error", "step-sequence-duplicate", t.object_key,
                f"TGB {t.tgb_id} appears at merged steps "
                f"{seen_ids[t.tgb_id]} and {step}: exactly-once is broken"))
        seen_ids[t.tgb_id] = step
        prev = last_seq.get(t.producer_id)
        if prev is not None and t.producer_seq <= prev:
            report.issues.append(FsckIssue(
                "error", "step-sequence-regression", t.object_key,
                f"producer {t.producer_id!r} sequence regresses "
                f"{prev} -> {t.producer_seq} at merged step {step}: the "
                f"global order is not a merge of per-producer streams"))
        last_seq[t.producer_id] = t.producer_seq
    for pid, last in last_seq.items():
        off = mv.producer_offset(pid)
        if off < last:
            report.issues.append(FsckIssue(
                "error", "step-sequence-unaccounted", ns.key("manifest"),
                f"producer {pid!r} has merged entries through seq {last} but "
                f"no shard map commits past offset {off}: a replacement "
                f"producer would re-emit committed work"))
    return mv


def _check_tgbs(ns: Namespace, view: Optional[DatasetView],
                report: FsckReport) -> None:
    store = ns.store
    trim = read_trim_marker(ns)
    safe_step = trim[0] if trim is not None else 0
    referenced = set()
    if view is not None:
        for i, t in enumerate(view.tgbs):
            referenced.add(t.object_key)
            report.checked_tgbs += 1
            step = view.base_step + i
            try:
                size = store.head(t.object_key)
            except (KeyError, NoSuchKey):
                if step < safe_step:
                    # legitimately reclaimed: physically deleted below the
                    # trim marker, still listed until producers' next
                    # logical trim advances base_step
                    continue
                report.issues.append(FsckIssue(
                    "error", "missing-tgb", t.object_key,
                    f"step {step} referenced by manifest v{view.version} "
                    f"(tgb_id={t.tgb_id}) but absent from the store"))
                continue
            if size != t.size_bytes:
                report.issues.append(FsckIssue(
                    "error", "tgb-size-mismatch", t.object_key,
                    f"manifest records {t.size_bytes} B, object is {size} B "
                    f"(torn commit)"))
    for key in store.list(ns.key("tgb")):
        if key in referenced:
            continue
        parsed = _parse_tgb_key(ns, key)
        if parsed is None:
            report.orphans.append(key)
            report.issues.append(FsckIssue(
                "warn", "orphan-tgb", key, "unparseable key, unreferenced"))
            continue
        pid, offset = parsed
        committed = view.producer_offset(pid) if view is not None else -1
        if offset <= committed:
            report.orphans.append(key)
            report.issues.append(FsckIssue(
                "warn", "orphan-tgb", key,
                f"producer {pid!r} committed through offset {committed} via "
                f"other objects; this one is superseded (safe to delete)"))
        else:
            report.pending.append(key)
            report.issues.append(FsckIssue(
                "warn", "pending-tgb", key,
                f"offset {offset} > committed {committed}: uncommitted — "
                f"either a live producer's pending TGB or a crashed "
                f"incarnation's leftover (not touched)"))


def _check_trim_skew(ns: Namespace, view: Optional[DatasetView],
                     versions: List[int], report: FsckReport) -> None:
    wms = read_watermarks(ns)
    trim = read_trim_marker(ns)
    if wms:
        min_step = min(w.step for w in wms.values())
        min_version = min(w.version for w in wms.values())
        if trim is not None:
            safe_step, safe_version = trim
            if safe_step > min_step:
                report.issues.append(FsckIssue(
                    "error", "trim-skew", ns.trim_key(),
                    f"trim marker safe_step={safe_step} passed the lowest "
                    f"checkpoint watermark step {min_step}: a restoring rank "
                    f"would find its batches reclaimed"))
            if safe_version > min_version:
                report.issues.append(FsckIssue(
                    "error", "trim-skew", ns.trim_key(),
                    f"trim marker safe_version={safe_version} passed the "
                    f"lowest watermark version {min_version}"))
        if view is not None and view.base_step > min_step:
            report.issues.append(FsckIssue(
                "error", "trim-skew", ns.manifest_key(view.version),
                f"latest manifest base_step={view.base_step} passed the "
                f"lowest watermark step {min_step}"))
        if versions:
            lowest_retained = versions[0]
            for rank, wm in sorted(wms.items()):
                if wm.version >= 0 and wm.version < lowest_retained:
                    report.issues.append(FsckIssue(
                        "error", "watermark-unreadable",
                        ns.watermark_key(rank),
                        f"rank {rank} checkpointed at manifest v{wm.version} "
                        f"but the oldest retained version is "
                        f"v{lowest_retained}: that checkpoint cannot "
                        f"restore"))
    elif trim is not None and trim[0] > 0:
        report.issues.append(FsckIssue(
            "warn", "trim-without-watermarks", ns.trim_key(),
            f"trim marker at safe_step={trim[0]} but no watermarks exist"))


def _stream_retained_versions(ns: Namespace, name: str) -> List[int]:
    return _manifest_versions(ns.stream(name))


def _check_runmanifest(ns: Namespace, versions: List[int],
                       report: FsckReport) -> None:
    """RunManifest <-> manifest <-> trim-marker consistency (aligned
    recovery): the latest committed entry must actually be restorable."""
    runs = RunManifestStore(ns)
    seqs = runs.seqs()
    if not seqs:
        return  # bare data-plane namespace: nothing aligned to audit
    for prev, cur in zip(seqs, seqs[1:]):
        if cur != prev + 1:
            report.issues.append(FsckIssue(
                "error", "torn-runmanifest-chain", runs.key(prev + 1),
                f"RunManifest sequence jumps {prev} -> {cur}"))
    entries = {}
    for seq in seqs:
        try:
            entries[seq] = runs.read(seq)
        except RunManifestError as e:
            report.issues.append(FsckIssue(
                "error", "corrupt-runmanifest", runs.key(seq), str(e)))
    latest = entries.get(seqs[-1])
    if latest is not None:
        _check_aligned_entry(ns, latest, versions, report, runs)
    _check_model_orphans(ns, entries, report)


def _check_aligned_entry(ns: Namespace, rm, versions: List[int],
                         report: FsckReport, runs) -> None:
    # -- model pointer intact -------------------------------------------------
    if rm.model_key:
        try:
            doc = msgpack.unpackb(ns.store.get(rm.model_key), raw=False)
        except (KeyError, NoSuchKey):
            report.issues.append(FsckIssue(
                "error", "missing-model-checkpoint", rm.model_key,
                f"RunManifest seq={rm.seq} binds a model checkpoint that is "
                f"absent from the store"))
            doc = None
        except Exception as e:
            report.issues.append(FsckIssue(
                "error", "torn-model-checkpoint", rm.model_key,
                f"cannot decode: {type(e).__name__}: {e}"))
            doc = None
        for e in (doc or {}).get("leaves", []):
            try:
                size = ns.store.head(e["key"])
            except (KeyError, NoSuchKey):
                report.issues.append(FsckIssue(
                    "error", "torn-model-checkpoint", e["key"],
                    f"leaf listed by {rm.model_key} is missing"))
                continue
            try:
                want = 1
                for dim in e["shape"]:
                    want *= dim
                want *= np.dtype(e["dtype"]).itemsize
            except Exception:
                continue  # extended dtype not decodable here: existence is enough
            if size != want:
                report.issues.append(FsckIssue(
                    "error", "torn-model-checkpoint", e["key"],
                    f"leaf is {size} B, MANIFEST records "
                    f"{e['shape']}/{e['dtype']} = {want} B"))
    # -- data cursor restorable ----------------------------------------------
    try:
        ck = Checkpoint.decode(rm.data_token)
    except ValueError as e:
        report.issues.append(FsckIssue(
            "error", "runmanifest-bad-cursor", runs.key(rm.seq), str(e)))
        return
    if ck.composite:
        for name, v, s in ck.streams:
            sns = ns.stream(name)
            retained = _stream_retained_versions(ns, name)
            if v >= 0 and (not retained or v < retained[0]
                           or v > retained[-1]):
                have = (f"retained versions are "
                        f"v{retained[0]}..v{retained[-1]}" if retained
                        else "no manifest versions are retained")
                report.issues.append(FsckIssue(
                    "error", "runmanifest-unreadable-cursor",
                    sns.manifest_key(v),
                    f"aligned cursor of stream {name!r} needs manifest v{v} "
                    f"but {have}: the aligned checkpoint cannot restore"))
            trim = read_trim_marker(sns)
            if trim is not None and trim[0] > s:
                report.issues.append(FsckIssue(
                    "error", "trim-skew", sns.trim_key(),
                    f"stream {name!r} trim marker safe_step={trim[0]} passed "
                    f"the aligned checkpoint's stream step {s}"))
    else:
        if ck.version >= 0 and (not versions or ck.version < versions[0]
                                or ck.version > versions[-1]):
            have = (f"retained versions are v{versions[0]}..v{versions[-1]}"
                    if versions else "no manifest versions are retained")
            report.issues.append(FsckIssue(
                "error", "runmanifest-unreadable-cursor",
                ns.manifest_key(ck.version),
                f"aligned cursor needs manifest v{ck.version} but {have}: "
                f"the aligned checkpoint cannot restore"))
        trim = read_trim_marker(ns)
        if trim is not None and trim[0] > rm.aligned_data_step():
            report.issues.append(FsckIssue(
                "error", "trim-skew", ns.trim_key(),
                f"trim marker safe_step={trim[0]} passed the aligned "
                f"checkpoint's data step {rm.aligned_data_step()}: an "
                f"aligned restore would find its batches reclaimed"))


def _check_model_orphans(ns: Namespace, entries: Dict[int, object],
                         report: FsckReport) -> None:
    """Model uploads never named by any RunManifest entry: a trainer killed
    between upload and commit. Superseded ones (below the latest bound
    position) are safe to delete; newer ones may be a live trainer
    mid-commit.

    Directory steps and entry positions are compared in *materialized*
    units — the unit TrainSession names directories in, invariant across
    elastic resizes — so a resized trainer's in-flight upload is never
    misjudged against a pre-resize entry's logical step.
    """
    from repro.train.checkpoint import checkpoint_dir_step

    if not entries:
        return
    referenced = {rm.model_key for rm in entries.values() if rm.model_key}
    # steps at which SOME entry bound a (possibly retry-tagged) directory: an
    # unbound sibling dir at such a step lost its commit race — a later
    # incarnation re-checkpointed the same cadence step — and is superseded
    # just as surely as one below the latest bound position
    bound_steps = set()
    for mkey in referenced:
        s = checkpoint_dir_step(mkey.split("/")[-2])
        if s is not None:
            bound_steps.add(s)
    latest_bound = -1
    for rm in entries.values():
        try:
            latest_bound = max(latest_bound, rm.aligned_data_step())
        except ValueError:
            pass  # undecodable cursor is reported by _check_aligned_entry
    by_dir: Dict[str, List[str]] = {}
    for key in ns.store.list(ns.key("checkpoints")):
        by_dir.setdefault(key.rsplit("/", 1)[0], []).append(key)
    for dirkey, keys in sorted(by_dir.items()):
        mkey = f"{dirkey}/MANIFEST.ckpt"
        if mkey in referenced:
            continue
        step = checkpoint_dir_step(dirkey.rsplit("/", 1)[-1])
        superseded = step is not None and (
            (latest_bound >= 0 and step < latest_bound)
            or step in bound_steps)
        if superseded:
            report.orphans.extend(sorted(keys))
            report.issues.append(FsckIssue(
                "warn", "orphan-model-checkpoint", dirkey,
                f"model upload at data step {step} was never bound by a "
                f"RunManifest entry and is superseded by a bound checkpoint "
                f"at data step "
                f"{step if step in bound_steps else latest_bound} "
                f"(safe to delete)"))
        else:
            report.pending.extend(sorted(keys))
            report.issues.append(FsckIssue(
                "warn", "pending-model-checkpoint", dirkey,
                f"model upload not (yet) bound by any RunManifest entry — "
                f"either a live trainer mid-commit or a crashed one's "
                f"leftover (not touched)"))


def _check_derive(ns: Namespace, view: Optional[DatasetView],
                  report: FsckReport,
                  parent_ns: Optional[Namespace]) -> None:
    """Derived-stream audits (streams produced by ``repro.graph``):

      * **derive cursor chain** — contiguous, decodable, non-regressive
        (src_step and out_seq both monotone), and never ahead of the
        manifest (a cursor binding outputs the manifest does not commit is
        a torn derive commit — the worker commits the cursor last).
      * **provenance-dangling** — a derived TGB whose provenance names
        source TGB ids the source stream's manifest no longer resolves.
        Warn severity: a legitimately trimmed source looks the same as a
        lost one from storage alone, and the derived bytes remain valid.
      * **orphan reclassification** — uncommitted TGB objects that carry a
        provenance footer and sit at/above the committed derive cursor's
        ``out_seq`` were uploaded by a window whose cursor never committed.
        Unlike a live raw producer's pending set, the restarted worker
        regenerates them deterministically (content-addressed), so they are
        *safe* orphans and ``--repair`` deletes them.
    """
    from repro.core.tgb import TGBReader
    from repro.graph.cursor import DeriveCursorError, DeriveCursorStore

    cur_store = DeriveCursorStore(ns)
    seqs = cur_store.seqs()
    for prev, cur in zip(seqs, seqs[1:]):
        if cur != prev + 1:
            report.issues.append(FsckIssue(
                "error", "torn-derive-cursor-chain", cur_store.key(prev + 1),
                f"derive cursor sequence jumps {prev} -> {cur}"))
    cursors = {}
    for seq in seqs:
        try:
            cursors[seq] = cur_store.read(seq)
        except DeriveCursorError as e:
            report.issues.append(FsckIssue(
                "error", "corrupt-derive-cursor", cur_store.key(seq), str(e)))
    prev_dc = None
    for seq in sorted(cursors):
        dc = cursors[seq]
        if prev_dc is not None and (dc.src_step < prev_dc.src_step
                                    or dc.out_seq < prev_dc.out_seq):
            report.issues.append(FsckIssue(
                "error", "regressive-derive-cursor", cur_store.key(seq),
                f"cursor seq {seq} rolls progress back: src_step "
                f"{prev_dc.src_step} -> {dc.src_step}, out_seq "
                f"{prev_dc.out_seq} -> {dc.out_seq}"))
        prev_dc = dc
    latest = cursors.get(seqs[-1]) if seqs else None
    if latest is not None and view is not None:
        committed = max((ps.committed_offset
                         for ps in view.producers.values()), default=-1)
        if latest.out_seq > committed + 1:
            report.issues.append(FsckIssue(
                "error", "torn-derive-commit", cur_store.key(latest.seq),
                f"derive cursor binds outputs through out_seq "
                f"{latest.out_seq} but the manifest commits only through "
                f"offset {committed} — the cursor must always commit last"))
    # -- provenance-dangling ---------------------------------------------------
    if view is not None and parent_ns is not None:
        src_ids: Dict[str, Optional[set]] = {}
        for step, t in view.derived_tgbs():
            src_name = t.provenance.get("src_stream", "")
            if src_name not in src_ids:
                from repro.core.manifest import open_manifest_store
                sns = parent_ns.stream(src_name)
                try:
                    sm = open_manifest_store(sns)
                    slatest = sm.latest_version()
                    sview = sm.load_view(slatest) if slatest >= 0 else None
                except Exception:
                    sview = None
                src_ids[src_name] = ({d.tgb_id for d in sview.tgbs}
                                     if sview is not None else None)
            ids = src_ids[src_name]
            missing = [i for i in t.provenance.get("src", [])
                       if ids is None or i not in ids]
            if missing:
                report.issues.append(FsckIssue(
                    "warn", "provenance-dangling", t.object_key,
                    f"derived TGB {t.tgb_id} (step {step}) cites source TGBs "
                    f"{missing} of stream {src_name!r} that its manifest no "
                    f"longer resolves (trimmed source, or lost lineage) — "
                    f"re-derivation from scratch is impossible"))
    # -- orphan reclassification -----------------------------------------------
    floor = latest.out_seq if latest is not None else 0
    for key in list(report.pending):
        parsed = _parse_tgb_key(ns, key)
        if parsed is None:
            continue
        _pid, offset = parsed
        try:
            footer = TGBReader(ns.store, key).footer()
        except Exception:
            continue  # unreadable pending object stays pending (not touched)
        if footer.provenance is None or offset < floor:
            continue
        report.pending.remove(key)
        report.orphans.append(key)
        report.issues[:] = [i for i in report.issues
                            if not (i.kind == "pending-tgb" and i.key == key)]
        report.issues.append(FsckIssue(
            "warn", "orphan-derived-tgb", key,
            f"derived output at offset {offset} has no committed derive "
            f"cursor (committed out_seq={floor}); a restarted worker "
            f"regenerates it content-addressed (safe to delete)"))


def fsck(ns: Namespace, repair: bool = False,
         recurse_streams: bool = True,
         parent_ns: Optional[Namespace] = None) -> FsckReport:
    """Audit one run namespace through the storage layer alone.

    ``repair=True`` deletes the *safely* orphaned objects (superseded
    duplicate TGBs below their producer's committed offset, derived outputs
    whose window never committed a derive cursor, and model uploads
    superseded by a later RunManifest entry) — never pending ones, never
    manifests. Returns the full :class:`FsckReport`.
    """
    report = FsckReport(namespace=ns.prefix)
    n_shards: Optional[int] = None
    try:
        n_shards = read_shard_config(ns)
    except Exception as e:
        report.issues.append(FsckIssue(
            "error", "corrupt-shard-config", ns.key("manifest", "shards.cfg"),
            f"cannot decode: {type(e).__name__}: {e}"))
    if n_shards is not None and n_shards > 1:
        view = _check_sharded(ns, n_shards, report)
        # downstream checks compare watermark / RunManifest cursor versions
        # against the retained range; on a sharded run versions are the
        # monotone merged scalar, for which any value up to the current head
        # is restorable (load_view treats the version as a floor)
        latest = view.version if view is not None else -1
        versions = list(range(0, latest + 1, max(1, latest))) if latest >= 0 \
            else []
    else:
        versions = _manifest_versions(ns)
        view = _check_chain(ns, versions, report)
    _check_tgbs(ns, view, report)
    _check_derive(ns, view, report, parent_ns)
    _check_trim_skew(ns, view, versions, report)
    _check_runmanifest(ns, versions, report)
    if repair and report.orphans:
        for key in list(report.orphans):
            ns.store.delete(key)
            report.repaired.append(key)
        report.orphans.clear()
    if recurse_streams:
        for name in list_streams(ns):
            report.streams[name] = fsck(ns.stream(name), repair=repair,
                                        recurse_streams=False, parent_ns=ns)
    return report
