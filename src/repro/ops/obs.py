"""Storage-native telemetry reads: the ``obs`` / ``top`` ops subcommands.

Everything here is computed from flight-recorder snapshots
(``<run>/obs/<component>/<seq>.snap``) plus the committed manifest chain —
no live process is consulted, so the same view works while a run is
executing and after every participant has exited (post-mortem).

Per component the summary carries the latest decoded snapshot, its age, and
**rates** derived by differencing the newest pair of snapshots from the same
incarnation (the ``inc`` token): a counter differenced across a process
restart would go negative, so rate math never crosses incarnations.

Family-specific derived fields:

  * ``producer.*``  — ingest throughput (bytes_committed/s), commit-conflict
    rate (conflicts / attempts), commit attempts/s;
  * ``consumer.*``  — read throughput (bytes_consumed/s), steps/s, retry
    count, and **ingestion lag**: the manifest frontier's total steps minus
    the steps this incarnation consumed (how far the reader trails what is
    already committed);
  * ``derive.*``    — windows completed, store-hit ratio;
  * ``store.*``     — resilience layer: hedge win rate (hedges_won /
    hedges_fired), breaker state rendered as closed/half-open/open, breaker
    opens, retry-budget exhaustions (brownout/outage diagnosis — see
    docs/OPERATIONS.md "Brownout and outage runbook").
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.manifest import open_manifest_store
from repro.core.objectstore import Namespace
from repro.obs.recorder import component_dirs, read_snapshots

__all__ = ["component_summary", "obs_summary", "render_obs", "render_top"]

#: snapshots read per component when computing rates (newest N)
RATE_WINDOW = 8


def _frontier(ns: Namespace) -> Optional[Dict[str, int]]:
    """The committed manifest frontier, or None before the first commit."""
    m = open_manifest_store(ns)
    v = m.latest_version()
    if v < 0:
        return None
    view = m.load_view(v)
    return {"version": v, "total_steps": view.total_steps}


def _fields(doc: Dict) -> Dict[str, object]:
    """Metric names with the ``<component>.`` prefix stripped."""
    comp = doc.get("component", "")
    pre = comp + "."
    out = {}
    for name, value in (doc.get("metrics") or {}).items():
        out[name[len(pre):] if name.startswith(pre) else name] = value
    return out


def _scalar(fields: Dict[str, object], key: str, default=0):
    v = fields.get(key, default)
    return v if isinstance(v, (int, float)) else default


def component_summary(ns: Namespace, component: str,
                      frontier: Optional[Dict[str, int]] = None) -> Dict:
    """One component's storage-side summary (see module docstring)."""
    snaps = read_snapshots(ns, component, last=RATE_WINDOW)
    if not snaps:
        return {"component": component, "snaps": 0}
    latest = snaps[-1]
    fields = _fields(latest)
    family = component.split(".", 1)[0]
    out: Dict[str, object] = {
        "component": component,
        "family": family,
        "snaps": len(snaps),
        "latest_seq": latest.get("seq"),
        "inc": latest.get("inc"),
        "wall": latest.get("wall"),
        "metrics": fields,
    }
    # rate math: newest earlier snapshot from the SAME incarnation
    prev = next((s for s in reversed(snaps[:-1])
                 if s.get("inc") == latest.get("inc")), None)
    rates: Dict[str, float] = {}
    if prev is not None:
        dt = float(latest.get("t", 0)) - float(prev.get("t", 0))
        if dt > 0:
            pf = _fields(prev)
            for key in fields:
                a, b = fields.get(key), pf.get(key)
                if isinstance(a, (int, float)) and isinstance(b, (int, float)):
                    rates[key + "_per_s"] = (a - b) / dt
    out["rates"] = rates
    # family-specific derived fields
    if family == "producer":
        attempts = _scalar(fields, "commit_attempts")
        out["conflict_rate"] = \
            _scalar(fields, "commit_conflicts") / max(1, attempts)
        out["throughput_Bps"] = rates.get("bytes_committed_per_s")
    elif family == "consumer":
        out["throughput_Bps"] = rates.get("bytes_consumed_per_s")
        out["steps_per_s"] = rates.get("steps_consumed_per_s")
        if frontier is not None:
            out["lag_steps"] = max(
                0, frontier["total_steps"] - _scalar(fields,
                                                     "steps_consumed"))
    elif family == "derive":
        derived = _scalar(fields, "tgbs_derived")
        out["store_hit_ratio"] = \
            _scalar(fields, "store_hits") / max(1, derived)
    elif family == "store":
        fired = _scalar(fields, "hedges_fired")
        out["hedge_win_rate"] = _scalar(fields, "hedges_won") / max(1, fired)
        out["breaker"] = {0: "closed", 1: "half-open", 2: "open"}.get(
            int(_scalar(fields, "breaker_state")), "?")
        out["throttled_per_s"] = rates.get("throttled_per_s")
    return out


def obs_summary(ns: Namespace, now: Optional[float] = None,
                recurse: bool = True) -> Dict:
    """The full storage-side telemetry view of one run namespace."""
    import time
    from repro.ops.fsck import list_streams

    now = time.time() if now is None else now
    frontier = _frontier(ns)
    components = []
    for comp in component_dirs(ns):
        row = component_summary(ns, comp, frontier=frontier)
        if row.get("wall") is not None:
            row["age_s"] = max(0.0, now - float(row["wall"]))
        components.append(row)
    out = {"namespace": ns.prefix, "frontier": frontier,
           "components": components}
    if recurse:
        streams = {}
        for name in list_streams(ns):
            streams[name] = obs_summary(ns.stream(name), now=now,
                                        recurse=False)
        if streams:
            out["streams"] = streams
    return out


# -- plain-text rendering ---------------------------------------------------

def _fmt_bytes(v) -> str:
    if v is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB"):
        if abs(v) < 1024 or unit == "GB":
            return f"{v:.1f}{unit}"
        v /= 1024.0
    return f"{v:.1f}GB"


def _fmt(v, spec="{:.2f}") -> str:
    return "-" if v is None else spec.format(v)


def render_top(summary: Dict, out, indent: str = "") -> None:
    """Compact one-row-per-component table (the ``top`` subcommand)."""
    fr = summary.get("frontier")
    frontier_txt = (f"frontier v{fr['version']} total_steps="
                    f"{fr['total_steps']}" if fr else "no manifests yet")
    print(f"{indent}{summary['namespace']}: {frontier_txt}", file=out)
    rows = summary.get("components", [])
    if not rows:
        print(f"{indent}  (no telemetry snapshots published)", file=out)
    else:
        hdr = (f"{'COMPONENT':28} {'AGE':>7} {'THROUGHPUT/s':>13} "
               f"{'STEPS/s':>8} {'LAG':>6} {'CONFLICT':>9} {'RETRY':>6}")
        print(indent + "  " + hdr, file=out)
        for row in rows:
            if row.get("snaps", 0) == 0:
                continue
            m = row.get("metrics", {})
            print(indent + "  " + (
                f"{row['component']:28} "
                f"{_fmt(row.get('age_s'), '{:.1f}s'):>7} "
                f"{_fmt_bytes(row.get('throughput_Bps')):>13} "
                f"{_fmt(row.get('steps_per_s'), '{:.2f}'):>8} "
                f"{_fmt(row.get('lag_steps'), '{:.0f}'):>6} "
                f"{_fmt(row.get('conflict_rate'), '{:.1%}'):>9} "
                f"{_scalar(m, 'read_retries', 0):>6}"), file=out)
    for name, sub in sorted(summary.get("streams", {}).items()):
        print(f"{indent}stream {name!r}:", file=out)
        render_top(sub, out, indent=indent + "  ")


def render_obs(summary: Dict, out, indent: str = "") -> None:
    """Full per-component metric dump (the ``obs`` subcommand)."""
    fr = summary.get("frontier")
    frontier_txt = (f"frontier v{fr['version']} total_steps="
                    f"{fr['total_steps']}" if fr else "no manifests yet")
    print(f"{indent}{summary['namespace']}: {frontier_txt}", file=out)
    for row in summary.get("components", []):
        if row.get("snaps", 0) == 0:
            print(f"{indent}  {row['component']}: no readable snapshots",
                  file=out)
            continue
        age = _fmt(row.get("age_s"), "{:.1f}s")
        print(f"{indent}  {row['component']} (seq {row['latest_seq']}, "
              f"inc {row['inc']}, {row['snaps']} snaps, age {age}):",
              file=out)
        for key, value in sorted(row.get("metrics", {}).items()):
            if isinstance(value, dict):  # histogram summary
                parts = ", ".join(f"{k}={_fmt(v)}" if isinstance(v, float)
                                  else f"{k}={v}"
                                  for k, v in sorted(value.items()))
                print(f"{indent}    {key}: {parts}", file=out)
            else:
                print(f"{indent}    {key}: {value}", file=out)
        for key, value in sorted(row.get("rates", {}).items()):
            print(f"{indent}    rate {key}: {value:.3f}", file=out)
    for name, sub in sorted(summary.get("streams", {}).items()):
        print(f"{indent}stream {name!r}:", file=out)
        render_obs(sub, out, indent=indent + "  ")
