"""``batchweave`` — the operator CLI (``python -m repro.ops``).

Three storage-native subcommands, per the paper's recovery design (every
piece of operational truth lives in the object store, so an operator tool
needs nothing but the namespace):

  * ``inspect`` — manifest chain, per-producer durable state, watermarks,
    trim marker, per-TGB derivation provenance; recurses into streams.
  * ``fsck``    — detect orphaned TGBs, torn commits / torn delta-manifest
    chains, trim-vs-checkpoint skew, torn derive-cursor chains, and
    provenance-dangling derived TGBs. ``--repair`` deletes safe orphans
    (including derived outputs with no committed derive cursor).
  * ``trim``    — run one watermark-driven reclamation cycle (logical trim
    marker + optional physical deletion), exactly what the background
    reclaimer does.
  * ``obs``     — dump every component's latest flight-recorder snapshot
    (full metric catalog + same-incarnation rates).
  * ``top``     — one row per component: throughput, steps/s, ingestion
    lag, commit-conflict rate — rendered purely from storage snapshots,
    so it works on live runs and post-mortem alike.

Exit codes: 0 = ok/clean, 1 = fsck found problems, 2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.core.lifecycle import Reclaimer
from repro.core.objectstore import FileObjectStore, Namespace, ObjectStore
from repro.ops.fsck import FsckReport, fsck, list_streams
from repro.ops.inspect import inspect_run

__all__ = ["build_parser", "main"]


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="batchweave",
        description="BatchWeave ops: inspect / fsck / trim a run namespace "
                    "purely through the storage layer.")
    ap.add_argument("--root", required=True,
                    help="filesystem object-store root (FileObjectStore dir)")
    ap.add_argument("--namespace", "-n", default="runs/dataplane",
                    help="run namespace prefix (default: runs/dataplane)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("inspect", help="summarize manifest chain, producer "
                                   "state, watermarks, trim marker")

    fs = sub.add_parser("fsck", help="detect orphans, torn commits, torn "
                                     "manifest chains, trim skew")
    fs.add_argument("--repair", action="store_true",
                    help="delete safely-orphaned TGB objects")

    tr = sub.add_parser("trim", help="run one reclamation cycle")
    tr.add_argument("--ranks", type=int, default=None,
                    help="expected checkpointing ranks (default: however "
                         "many watermarks exist)")
    tr.add_argument("--logical-only", action="store_true",
                    help="only advance the trim marker; no deletion")

    sub.add_parser("obs", help="dump flight-recorder snapshots (full metric "
                               "catalog per component)")
    sub.add_parser("top", help="per-component throughput / lag / conflict "
                               "table from storage snapshots")
    return ap


def _print_fsck(report: FsckReport, as_json: bool, out) -> None:
    if as_json:
        def enc(r: FsckReport) -> dict:
            return {
                "namespace": r.namespace, "clean": r.clean,
                "checked_manifests": r.checked_manifests,
                "checked_tgbs": r.checked_tgbs,
                "orphans": r.orphans, "pending": r.pending,
                "repaired": r.repaired,
                "issues": [vars(i) for i in r.issues],
                "streams": {k: enc(v) for k, v in r.streams.items()},
            }
        json.dump(enc(report), out, indent=2)
        out.write("\n")
        return
    print(report.summary(), file=out)
    for issue in report.issues:
        print(f"  {issue}", file=out)
    for key in report.repaired:
        print(f"  [repaired] deleted {key}", file=out)
    for name, sr in sorted(report.streams.items()):
        print(f"stream {name!r}: {sr.summary()}", file=out)
        for issue in sr.issues:
            print(f"  {issue}", file=out)
        for key in sr.repaired:
            print(f"  [repaired] deleted {key}", file=out)


def _run_trim(ns: Namespace, ranks: Optional[int], logical_only: bool,
              as_json: bool, out) -> None:
    targets = [("", ns)] + [(name, ns.stream(name))
                            for name in list_streams(ns)]
    rows = []
    for name, tns in targets:
        r = Reclaimer(tns, expected_ranks=ranks,
                      physical_delete=not logical_only)
        wg = r.run_cycle()
        rows.append({
            "stream": name or None,
            "advanced": wg is not None,
            "safe_step": wg.step if wg else None,
            "safe_version": wg.version if wg else None,
            "tgbs_deleted": r.stats.tgbs_deleted,
            "manifests_deleted": r.stats.manifests_deleted,
            "bytes_reclaimed": r.stats.bytes_reclaimed,
        })
    if as_json:
        json.dump(rows, out, indent=2)
        out.write("\n")
        return
    for row in rows:
        label = f"stream {row['stream']!r}" if row["stream"] else ns.prefix
        if not row["advanced"]:
            print(f"trim {label}: no global watermark yet (nothing trimmed)",
                  file=out)
        else:
            print(f"trim {label}: safe_step={row['safe_step']} "
                  f"safe_version={row['safe_version']} "
                  f"deleted {row['tgbs_deleted']} tgbs / "
                  f"{row['manifests_deleted']} manifests "
                  f"({row['bytes_reclaimed']} B)", file=out)


def main(argv: Optional[List[str]] = None, store: Optional[ObjectStore] = None,
         out=None) -> int:
    """CLI entry point. ``store``/``out`` are injectable for tests."""
    out = out or sys.stdout
    parser = build_parser()
    args = parser.parse_args(argv)
    if store is None:
        import os
        if not os.path.isdir(args.root):
            # never create the store as a side effect of an audit — a typo'd
            # --root must fail loudly, not report a fresh empty run as clean
            parser.error(f"--root {args.root!r} does not exist")
        store = FileObjectStore(args.root)
    ns = Namespace(store, args.namespace)
    if args.cmd == "inspect":
        info = inspect_run(ns)
        if args.as_json:
            json.dump(info, out, indent=2)
            out.write("\n")
        else:
            _print_inspect(info, out)
        return 0
    if args.cmd == "fsck":
        report = fsck(ns, repair=args.repair)
        _print_fsck(report, args.as_json, out)
        # like fsck(8): nonzero if problems were found, even when --repair
        # just corrected them — scripts learn the namespace *was* dirty
        repaired = bool(report.repaired) or \
            any(r.repaired for r in report.streams.values())
        return 0 if report.clean and not repaired else 1
    if args.cmd == "trim":
        _run_trim(ns, args.ranks, args.logical_only, args.as_json, out)
        return 0
    if args.cmd in ("obs", "top"):
        from repro.ops.obs import obs_summary, render_obs, render_top
        summary = obs_summary(ns)
        if args.as_json:
            json.dump(summary, out, indent=2)
            out.write("\n")
        elif args.cmd == "top":
            render_top(summary, out)
        else:
            render_obs(summary, out)
        return 0
    return 2  # unreachable: argparse enforces the subcommand


def _print_inspect(info: dict, out, indent: str = "") -> None:
    m = info["manifests"]
    print(f"{indent}namespace {info['namespace']}", file=out)
    if m["latest"] is None:
        print(f"{indent}  no manifests committed yet "
              f"({info['tgb_objects']} tgb objects)", file=out)
    else:
        print(f"{indent}  manifests: v{m['oldest']}..v{m['latest']} retained "
              f"({m['retained']}), format={m.get('format')}, "
              f"latest={m.get('bytes')} B", file=out)
        v = info["view"]
        print(f"{indent}  view: base_step={v['base_step']} "
              f"total_steps={v['total_steps']} live_tgbs={v['live_tgbs']} "
              f"({v['live_bytes']} B); {info['tgb_objects']} tgb objects on "
              f"store", file=out)
        for pid, st in info["producers"].items():
            print(f"{indent}  producer {pid}: "
                  f"committed_offset={st['committed_offset']} "
                  f"last_commit=v{st['last_commit_version']} "
                  f"epoch={st['epoch']}", file=out)
    for rank, wm in info["watermarks"].items():
        print(f"{indent}  watermark rank{rank}: v{wm['version']} "
              f"step={wm['step']}", file=out)
    if info["trim"]:
        print(f"{indent}  trim marker: safe_step={info['trim']['safe_step']} "
              f"safe_version={info['trim']['safe_version']}", file=out)
    dv = info.get("derive")
    if dv:
        cur = dv.get("cursor")
        if cur:
            print(f"{indent}  derive cursor: seq={cur['seq']} "
                  f"src_step={cur['src_step']} out_seq={cur['out_seq']} "
                  f"op={cur['op']} graph={cur['graph'][:12]}…", file=out)
        elif dv.get("cursor_error"):
            print(f"{indent}  derive cursor: UNREADABLE "
                  f"({dv['cursor_error']})", file=out)
        for t in dv.get("derived_tgbs", []):
            print(f"{indent}  derived step {t['step']} ({t['tgb_id']}): "
                  f"{t['op']} over {t['src_stream']!r}"
                  f"[{', '.join(t['src'])}] k={t['out_index']} "
                  f"params={t['params'][:12]}…", file=out)
    rm = info.get("runmanifest")
    if rm:
        if "error" in rm:
            print(f"{indent}  runmanifest: {rm['entries']} entries, latest "
                  f"seq {rm['latest']} UNREADABLE ({rm['error']})", file=out)
        else:
            a = rm["aligned"]
            print(f"{indent}  runmanifest: {rm['entries']} entries; aligned "
                  f"@ step {a['step']} (dp={a['topology'][0]} "
                  f"cp={a['topology'][1]}, data_dp={a['data_dp']}, "
                  f"data_step={a['data_step']}) model={a['model_key']}",
                  file=out)
            for sname, cur in (a["streams"] or {}).items():
                print(f"{indent}    stream {sname!r} cursor: "
                      f"v{cur['version']} step={cur['step']}", file=out)
    for name, sub in sorted(info.get("streams", {}).items()):
        print(f"{indent}  stream {name!r}:", file=out)
        _print_inspect(sub, out, indent=indent + "  ")
