"""Logical-axis sharding rules over the production mesh (pod, data, model).

Physical strategy (MaxText-style 2D/3D sharding):

  * batch                 -> ("pod", "data")          pure DP
  * weight "embed" dims   -> ("pod", "data")          FSDP (ZeRO-3): weights and
                                                      optimizer state fully
                                                      sharded; all-gathered
                                                      per-layer inside the scan
  * "heads"/"kv"/"mlp"/"vocab"/"experts" -> "model"   TP / EP
  * "seq_sp"              -> "model"                  sequence-parallel
                                                      activation constraint
                                                      (only when heads are not
                                                      TP-shardable: 40H, 24H)
  * "cache_seq"           -> "model"                  decode KV caches shard the
                                                      sequence dim (flash-decode
                                                      style all-reduce softmax)

Every mapping entry degrades to ``None`` (replicated) when the dimension is not
divisible by the mesh axis size — jit in_shardings require divisibility.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Physical = Optional[Tuple[str, ...]]

_current_rules: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_rules", default=None)


def current_rules() -> Optional["ShardingRules"]:
    return _current_rules.get()


@dataclass
class ShardingRules:
    mesh: Mesh
    mapping: Dict[str, Physical]
    constrain_activations: bool = True

    def axis_size(self, axes: Physical) -> int:
        if not axes:
            return 1
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> PartitionSpec:
        """PartitionSpec for the given logical axes; if ``shape`` is given,
        non-divisible dims degrade to replicated."""
        entries = []
        used: set = set()
        for i, ax in enumerate(logical_axes):
            phys = self.mapping.get(ax) if ax is not None else None
            if phys:
                # an axis name may appear only once in a PartitionSpec
                phys = tuple(p for p in phys if p not in used)
            if not phys:
                entries.append(None)
                continue
            if shape is not None:
                n = 1
                for p in phys:
                    n *= self.mesh.shape[p]
                if shape[i] % n != 0:
                    entries.append(None)
                    continue
            used.update(phys)
            entries.append(phys if len(phys) > 1 else phys[0])
        while entries and entries[-1] is None:
            entries.pop()
        return PartitionSpec(*entries)

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def constrain(self, x: jax.Array, logical_axes: Sequence[Optional[str]]):
        if not self.constrain_activations:
            return x
        try:
            spec = self.spec(logical_axes, shape=x.shape)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, spec))
        except Exception:
            return x


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_rules(mesh: Mesh, num_heads: int, num_kv_heads: int,
               seq_parallel: bool = True,
               fsdp: bool = True,
               experts_ep: bool = True) -> ShardingRules:
    """Build the logical->physical mapping for one architecture on one mesh."""
    dax = data_axes(mesh)
    model = ("model",) if "model" in mesh.axis_names else None
    msize = mesh.shape["model"] if model else 1
    heads_tp = model if (model and num_heads % msize == 0) else None
    kv_tp = model if (model and num_kv_heads % msize == 0) else None
    mapping: Dict[str, Physical] = {
        "batch": dax or None,
        "embed": dax if fsdp else None,
        "heads": heads_tp,
        "kv": kv_tp,
        "mlp": model,
        "vocab": model,
        "experts": model if experts_ep else None,
        "layers": None,
        "state": None,
        "cache_seq": model,
        # sequence-parallel q when heads cannot be TP-sharded; otherwise the
        # head dim carries TP and seq stays unsharded.
        "seq_sp": model if (seq_parallel and heads_tp is None) else None,
        # residual-stream sequence sharding (classic SP) — opt-in knob used by
        # perf iterations; default off to keep baseline faithful.
        "seq_res": None,
    }
    return ShardingRules(mesh=mesh, mapping=mapping)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    token = _current_rules.set(rules)
    try:
        yield rules
    finally:
        _current_rules.reset(token)


def param_shardings(rules: ShardingRules, specs):
    """NamedSharding tree for a ParamSpec tree."""
    from repro.models.common import spec_tree_map
    return spec_tree_map(
        lambda s: rules.sharding(s.logical_axes, s.shape), specs)
