"""Static HLO analysis for the roofline: loop-corrected FLOPs, bytes, collectives.

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified on this
backend: an 8-iteration scan reports 1/8 the unrolled FLOPs), which would
drastically undercount scanned-layer models. This module parses the post-SPMD
HLO text instead:

  * builds the computation call graph (while bodies, fusions, to_apply),
  * multiplies every instruction's cost by the product of enclosing
    ``known_trip_count`` values,
  * FLOPs from ``dot`` ops (2 x prod(output_shape) x contraction size); our
    models lower all heavy math to dots,
  * bytes from operand+output sizes at fusion boundaries (fusion internals are
    free — the fusion op itself carries the HBM traffic),
  * collective link-bytes per op kind with replica-group size:
        all-gather          output_bytes            (ring, (g-1)/g ~= 1)
        reduce-scatter      output_bytes x (g-1)
        all-reduce          2 x output_bytes        (RS + AG)
        all-to-all          output_bytes
        collective-permute  output_bytes

All sizes are PER DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


@dataclass
class Instruction:
    name: str
    type_str: str
    op: str
    line: str


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    is_entry: bool = False


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->")


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            name = mc.group(2)
            cur = Computation(name=name, is_entry=bool(mc.group(1)))
            comps[name] = cur
            if mc.group(1):
                entry = name
            continue
        mi = _INSTR_RE.match(line)
        if mi and cur is not None:
            cur.instructions.append(Instruction(
                name=mi.group(1), type_str=mi.group(2), op=mi.group(3),
                line=line))
    return comps, entry


def _trip_count(line: str) -> int:
    m = re.search(r'known_trip_count[":{ ]+n["\s:]+["\']?(\d+)', line)
    return int(m.group(1)) if m else 1


def _called_computations(line: str) -> List[Tuple[str, str]]:
    """[(kind, comp_name)] referenced by this instruction."""
    out = []
    for kind in ("body", "condition", "calls", "to_apply", "branch_computations"):
        for m in re.finditer(kind + r"=\{?([%\w\.\-, ]+)\}?", line):
            for name in m.group(1).split(","):
                name = name.strip()
                if name.startswith("%"):
                    out.append((kind, name))
    return out


def _replica_group_size(line: str) -> int:
    # iota form: replica_groups=[num_groups,group_size]<=[...]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    # explicit form: replica_groups={{0,1,2,...},{...}}
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(ids))
    return 1


def _dot_flops(inst: Instruction, shapes: Dict[str, str]) -> float:
    out_elems = _shape_elems(inst.type_str)
    # contraction size from lhs shape + lhs_contracting_dims
    operands = _operands(inst)
    mc = re.search(r"lhs_contracting_dims=\{([0-9, ]*)\}", inst.line)
    k = 1
    if mc and operands:
        lhs = operands[0]
        # the operand list usually carries the type inline
        # (``dot(f32[32,64]{1,0} %lhs, ...)``); fall back to the module-wide
        # shape table for the untyped ``dot(%lhs, %rhs)`` form
        mt = re.search(r"([a-z0-9]+\[[0-9,]*\][^\s]*)\s+" + re.escape(lhs)
                       + r"[,)]", inst.line)
        lhs_type = mt.group(1) if mt else shapes.get(lhs, "")
        ms = _SHAPE_RE.search(lhs_type)
        if ms and ms.group(2):
            dims = [int(d) for d in ms.group(2).split(",")]
            for di in mc.group(1).split(","):
                di = di.strip()
                if di:
                    idx = int(di)
                    if idx < len(dims):
                        k *= dims[idx]
    return 2.0 * out_elems * k


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "iota", "while", "conditional", "call",
    "custom-call", "rng-bit-generator", "partition-id", "replica-id",
}

_SLICE_READ_OPS = {"dynamic-slice", "gather"}


def _is_convert_only(comp: "Computation") -> bool:
    """True for fusion computations that only convert dtypes (possibly with a
    broadcast/reshape). The CPU backend has no native bf16 matmul, so it wraps
    every dot in bf16->f32 converts; on the TPU target the MXU consumes bf16
    with fp32 accumulation and these materializations don't exist. We charge
    them zero bytes (documented CPU-lowering artifact)."""
    real = [i for i in comp.instructions
            if i.op not in ("parameter", "ROOT")]
    ops = {i.op for i in real}
    return bool(ops) and ops <= {"convert", "broadcast", "reshape", "copy",
                                 "bitcast"}


_OPERAND_NAME_RE = re.compile(r"%[\w\.\-]+")


def _operands(inst: Instruction) -> List[str]:
    """Operand names, handling both ``op(%a, %b)`` and the typed form
    ``op(f32[2,3]{1,0} %a, f32[3]{0} %b)`` newer XLA emits."""
    mo = re.search(r"\(([^)]*)\)", inst.line[inst.line.index(inst.op):])
    if not mo:
        return []
    return _OPERAND_NAME_RE.findall(mo.group(1))


def _fusion_effective_bytes(fusion_inst: Instruction,
                            comps: Dict[str, "Computation"],
                            shapes: Dict[str, str]) -> float:
    """HBM bytes for a fusion op, modeling slice/in-place semantics.

    A fusion parameter that is only touched via dynamic-slice / gather is
    charged those slices' output bytes (scan xs reads); a parameter that is the
    in-place target of a dynamic-update-slice is charged the update bytes (scan
    ys writes) — NOT the full loop-carried buffer. Everything else pays full
    operand bytes, plus the fusion's output (with the root-DUS in-place
    adjustment).
    """
    called = [c for k, c in _called_computations(fusion_inst.line)
              if k == "calls"]
    operands = _operands(fusion_inst)
    comp = comps.get(called[0]) if called else None
    if comp is None:
        b = _shape_bytes(fusion_inst.type_str)
        return b + sum(_shape_bytes(shapes.get(o, "")) for o in operands)
    if _is_convert_only(comp):
        return 0.0

    # param name -> operand position; view chains (convert/bitcast/copy/
    # reshape of a param) resolve back to the param.
    params: Dict[str, int] = {}
    local_shapes: Dict[str, str] = {}
    view_of: Dict[str, str] = {}
    _VIEW_OPS = {"convert", "bitcast", "bitcast-convert", "copy", "reshape"}
    for inst in comp.instructions:
        local_shapes[inst.name] = inst.type_str
        if inst.op == "parameter":
            mo = re.search(r"parameter\((\d+)\)", inst.line)
            if mo:
                params[inst.name] = int(mo.group(1))
        elif inst.op in _VIEW_OPS:
            ops = _operands(inst)
            if len(ops) == 1:
                view_of[inst.name] = ops[0]

    def resolve(name: str) -> str:
        seen = 0
        while name in view_of and seen < 8:
            name = view_of[name]
            seen += 1
        return name

    full_use: Dict[int, bool] = {i: False for i in params.values()}
    slice_bytes: Dict[int, float] = {i: 0.0 for i in params.values()}
    dus_target: Dict[int, float] = {}
    root_is_dus_on_param = False
    for inst in comp.instructions:
        if inst.op in _VIEW_OPS:
            continue  # views are free; real uses charged at the consumer
        ops = _operands(inst)
        for pos, o in enumerate(ops):
            o = resolve(o)
            if o not in params:
                continue
            idx = params[o]
            if inst.op in _SLICE_READ_OPS and pos == 0:
                slice_bytes[idx] += _shape_bytes(inst.type_str)
            elif inst.op == "dynamic-update-slice" and pos == 0:
                upd = ops[1] if len(ops) > 1 else None
                ub = _shape_bytes(local_shapes.get(upd, "")) if upd else 0
                dus_target[idx] = dus_target.get(idx, 0.0) + ub
                if "ROOT" in inst.line:
                    root_is_dus_on_param = True
            else:
                full_use[idx] = True

    total = 0.0
    for name, idx in params.items():
        opd = operands[idx] if idx < len(operands) else None
        fullb = _shape_bytes(shapes.get(opd, "")) if opd else 0
        if full_use[idx]:
            total += fullb
        else:
            total += min(fullb, slice_bytes[idx] + dus_target.get(idx, 0.0))
    out_b = _shape_bytes(fusion_inst.type_str)
    if root_is_dus_on_param:
        # in-place update: the write is the update slice, not the buffer
        out_b = sum(dus_target.values())
    return total + out_b


@dataclass
class HLOCosts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)
    dot_count: int = 0
    while_loops: List[Tuple[str, int]] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str) -> HLOCosts:
    comps, entry = parse_module(text)
    # module-wide shape table (instruction names are unique per computation;
    # collisions across computations are rare and harmless for dot-K lookup)
    shapes: Dict[str, str] = {}
    for comp in comps.values():
        for inst in comp.instructions:
            shapes[inst.name] = inst.type_str
        # parameters appear as instructions with op 'parameter' (already added)

    # values that are dtype-converts of narrower values: charge the SOURCE
    # bytes when read (the f32 materialization is a CPU-lowering artifact;
    # the TPU MXU reads bf16 directly)
    src_bytes: Dict[str, float] = {}
    for comp in comps.values():
        for inst in comp.instructions:
            ops_ = _operands(inst)
            if inst.op == "convert" and len(ops_) == 1:
                src = ops_[0]
                if src in shapes:
                    src_bytes[inst.name] = min(_shape_bytes(shapes[src]),
                                               _shape_bytes(inst.type_str))
            elif inst.op == "fusion":
                called = [c for k, c in _called_computations(inst.line)
                          if k == "calls"]
                fcomp = comps.get(called[0]) if called else None
                if fcomp is not None and _is_convert_only(fcomp) and ops_:
                    inb = sum(_shape_bytes(shapes.get(o, "")) for o in ops_)
                    src_bytes[inst.name] = min(inb,
                                               _shape_bytes(inst.type_str))

    def eff_bytes(name: str) -> float:
        if name in src_bytes:
            return src_bytes[name]
        return _shape_bytes(shapes.get(name, ""))

    costs = HLOCosts()
    # multipliers per computation via DFS from entry
    mult: Dict[str, float] = {}

    def visit(comp_name: str, m: float, in_fusion: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        mult[comp_name] = mult.get(comp_name, 0.0) + m
        for inst in comp.instructions:
            calls = _called_computations(inst.line)
            if inst.op == "while":
                tc = _trip_count(inst.line)
                costs.while_loops.append((inst.name, tc))
                for kind, child in calls:
                    visit(child, m * (tc if kind == "body" else 1), in_fusion)
                continue
            if inst.op == "fusion":
                for _kind, child in calls:
                    visit(child, m, True)  # fusion internals: flops yes, bytes no
                continue
            for _kind, child in calls:
                visit(child, m, in_fusion)

        for inst in comp.instructions:
            if inst.op == "dot":
                costs.flops += _dot_flops(inst, shapes) * m
                costs.dot_count += 1
            if inst.op in _COLLECTIVES or any(
                    inst.op.startswith(c) for c in _COLLECTIVES):
                opk = next(c for c in _COLLECTIVES if inst.op.startswith(c))
                g = _replica_group_size(inst.line)
                out_b = _shape_bytes(inst.type_str)
                # CPU-backend dtype correction: collectives whose operands are
                # f32 converts of bf16 values (the CPU bf16-matmul wrapper)
                # would run at bf16 width on the TPU target.
                ops_c = _operands(inst)
                if ops_c:
                    src_b = sum(src_bytes.get(o, _shape_bytes(shapes.get(o, "")))
                                for o in ops_c)
                    if 0 < src_b < out_b:
                        out_b = src_b
                if opk == "all-reduce":
                    link = 2.0 * out_b * (g - 1) / max(1, g)
                elif opk == "reduce-scatter":
                    link = out_b * (g - 1)
                elif opk == "all-gather":
                    link = out_b * (g - 1) / max(1, g)
                else:
                    link = out_b * (g - 1) / max(1, g)
                costs.collective_bytes[opk] = costs.collective_bytes.get(opk, 0.0) + link * m
                costs.collective_count[opk] = costs.collective_count.get(opk, 0) + int(m)
            if not in_fusion and inst.op not in _SKIP_BYTES_OPS:
                if inst.op == "fusion":
                    b = _fusion_effective_bytes(inst, comps, shapes)
                elif inst.op in _SLICE_READ_OPS:
                    b = 2.0 * _shape_bytes(inst.type_str)
                elif inst.op == "dynamic-update-slice":
                    ops_ = _operands(inst)
                    ub = _shape_bytes(shapes.get(ops_[1], "")) if len(ops_) > 1 else 0
                    b = 2.0 * ub
                elif inst.op == "convert":
                    b = 0.0  # CPU bf16-matmul artifact; fused on TPU
                else:
                    b = _shape_bytes(inst.type_str)
                    for operand in _operands(inst):
                        if operand in shapes:
                            b += eff_bytes(operand)
                costs.bytes_accessed += b * m

    visit(entry, 1.0, False)
    return costs
