"""Roofline analysis over dry-run artifacts (assignment §ROOFLINE ANALYSIS).

Per (arch x shape x mesh) cell, from the loop-corrected HLO analysis:

    compute term    = HLO_FLOPs_per_device / 197e12          [bf16 peak/chip]
    memory term     = HLO_bytes_per_device / 819e9            [HBM BW/chip]
    collective term = collective_link_bytes_per_device / 4.5e10 [ICI BW/chip]

(The SPMD HLO is the per-device program, so HLO numbers are already per chip;
dividing by per-chip peaks is the assignment's formula with both sides divided
by `chips`.) MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE), D = tokens per
step (decode: global_batch, one new token each).

Usage:
    python -m repro.launch.roofline --dir experiments/dryrun [--csv out.csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12       # bf16 / chip (v5e)
HBM_BW = 819e9            # B/s / chip
ICI_BW = 4.5e10           # usable B/s per link (~50 GB/s/link nominal)
HBM_PER_CHIP = 16 * 2**30


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    hlo_flops_total: float = 0.0
    useful_ratio: float = 0.0
    step_time_s: float = 0.0
    mfu: float = 0.0
    peak_mem_gib: float = 0.0
    reason: str = ""


def tokens_per_step(rec: dict) -> int:
    if rec["kind"] == "decode":
        return rec["global_batch"]          # one new token per sequence
    return rec["global_batch"] * rec["seq_len"]


def model_flops(rec: dict) -> float:
    n = rec["active_params"] if rec["family"] == "moe" else rec["params"]
    d = tokens_per_step(rec)
    factor = 6.0 if rec["kind"] == "train" else 2.0  # fwd-only for serving
    return factor * n * d


def chips(rec: dict) -> int:
    return 512 if rec["mesh"] == "2x16x16" else 256


def ideal_step_s(n_params: float, tokens: int, kind: str = "train",
                 n_chips: int = 1, peak_flops: float = PEAK_FLOPS) -> float:
    """Roofline-ideal step seconds: MODEL_FLOPS / aggregate peak.

    The fused-train loop (``train/pipeline.py``, fig17) divides measured
    compute time by this to place each run on the roofline: compute drifting
    away from the ideal is a kernel/model regression, while data-wait growing
    under flat compute-vs-roofline indicts the data plane.
    """
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_params * tokens / (n_chips * peak_flops)


def analyze_record(rec: dict) -> RooflineRow:
    row = RooflineRow(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                      status=rec["status"], reason=rec.get("reason", ""))
    if rec["status"] != "ok":
        return row
    h = rec["hlo"]
    row.compute_s = h["flops"] / PEAK_FLOPS
    row.memory_s = h["bytes_accessed"] / HBM_BW
    row.collective_s = h["total_collective_bytes"] / ICI_BW
    terms = {"compute": row.compute_s, "memory": row.memory_s,
             "collective": row.collective_s}
    row.dominant = max(terms, key=terms.get)
    row.model_flops = model_flops(rec)
    row.hlo_flops_total = h["flops"] * chips(rec)
    row.useful_ratio = row.model_flops / max(1.0, row.hlo_flops_total)
    # roofline step time: max of the three overlapped terms (optimistic) —
    # we also report the sum-bound in the CSV consumer if needed.
    row.step_time_s = max(row.compute_s, row.memory_s, row.collective_s)
    ideal = row.model_flops / (chips(rec) * PEAK_FLOPS)
    row.mfu = ideal / row.step_time_s if row.step_time_s > 0 else 0.0
    row.peak_mem_gib = rec["memory"]["peak_per_device_bytes"] / 2**30
    return row


def load_rows(dirpath: str, tag: Optional[str] = None) -> List[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        if tag and not path.endswith(f"__{tag}.json"):
            continue
        with open(path) as f:
            rec = json.load(f)
        rows.append(analyze_record(rec))
    return rows


def format_table(rows: List[RooflineRow]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'status':8s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
           f"{'dominant':>10s} {'useful':>7s} {'MFU':>6s} {'mem GiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.status != "ok":
            lines.append(f"{r.arch:22s} {r.shape:12s} {r.mesh:8s} {r.status:8s}"
                         f"  -- {r.reason[:70]}")
            continue
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.mesh:8s} {r.status:8s} "
            f"{r.compute_s:10.4f} {r.memory_s:10.4f} {r.collective_s:10.4f} "
            f"{r.dominant:>10s} {r.useful_ratio:7.3f} {r.mfu:6.3f} "
            f"{r.peak_mem_gib:8.2f}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    rows = load_rows(args.dir, tag=args.tag)
    print(format_table(rows))
    if args.csv:
        import csv
        with open(args.csv, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["arch", "shape", "mesh", "status", "compute_s",
                        "memory_s", "collective_s", "dominant", "model_flops",
                        "hlo_flops_total", "useful_ratio", "step_time_s",
                        "mfu", "peak_mem_gib", "reason"])
            for r in rows:
                w.writerow([r.arch, r.shape, r.mesh, r.status, r.compute_s,
                            r.memory_s, r.collective_s, r.dominant,
                            r.model_flops, r.hlo_flops_total, r.useful_ratio,
                            r.step_time_s, r.mfu, r.peak_mem_gib, r.reason])


if __name__ == "__main__":
    main()
