import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: AOT lower + compile every (architecture x input shape)
on the production meshes, proving the distribution config is coherent.

The two lines above MUST precede any other import (jax locks the device count
on first init); do NOT set this flag globally — smoke tests and benches see
one device.

Per cell this prints/records:
  * compiled.memory_analysis()  — per-device bytes (proves it fits a 16 GB v5e)
  * compiled.cost_analysis()    — raw XLA numbers (loop bodies counted once)
  * loop-corrected static HLO analysis (repro.launch.hlo_analysis): FLOPs,
    bytes, per-kind collective link-bytes — the roofline inputs.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --mesh both
  python -m repro.launch.dryrun --arch all --shape all --mesh both --out experiments/dryrun
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCH_IDS, canonical, get_config
from repro.configs.shapes import SHAPES, shape_applicable
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.common import abstract_params
from repro.models.config import ModelConfig
from repro.sharding.specs import make_rules, param_shardings, use_rules
from repro.train.optimizer import OptimizerConfig
from repro.train.step import StepConfig, input_specs, make_train_step


def _repl(mesh):
    return NamedSharding(mesh, P())


def default_microbatches(cfg: ModelConfig, global_batch: int, dp: int) -> int:
    """Largest power-of-two microbatch count keeping (GB/n) % dp == 0 and
    per-device microbatch around 1-2 sequences for big models."""
    n = 1
    target = 8 if cfg.d_model >= 4096 else 2
    while n < target and (global_batch // (n * 2)) % dp == 0 \
            and global_batch // (n * 2) >= dp:
        n *= 2
    return n


def build_cell(cfg: ModelConfig, shape_name: str, mesh,
               microbatches: Optional[int] = None,
               seq_res: bool = False,
               overrides: Optional[Dict[str, Any]] = None,
               opt_overrides: Optional[Dict[str, Any]] = None,
               grad_accum_dtype: str = "float32"):
    """Returns (fn, args, in_shardings, out_shardings, donate, meta)."""
    shape = SHAPES[shape_name]
    if overrides:
        cfg = cfg.replace(**overrides)
    rules = make_rules(mesh, cfg.num_heads, cfg.num_kv_heads)
    if seq_res:
        rules.mapping["seq_res"] = ("model",)
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]

    specs = M.param_specs(cfg)
    params_sds = abstract_params(specs)
    params_sh = param_shardings(rules, specs)

    binputs = input_specs(cfg, shape.global_batch, shape.seq_len, shape.kind)
    batch_sds = {k: s for k, (s, _a) in binputs.items()}
    batch_sh = {k: rules.sharding(a, s.shape) for k, (s, a) in binputs.items()}

    meta = {"dp": dp, "rules": {k: list(v) if v else None
                                for k, v in rules.mapping.items()}}

    if shape.kind == "train":
        n_micro = microbatches or default_microbatches(
            cfg, shape.global_batch, dp)
        meta["microbatches"] = n_micro
        opt_cfg = OptimizerConfig(**(opt_overrides or {}))
        meta["opt_state_dtype"] = opt_cfg.state_dtype
        opt_dt = jnp.dtype(opt_cfg.state_dtype)
        train_step = make_train_step(
            cfg, opt_cfg,
            StepConfig(microbatches=n_micro,
                       grad_accum_dtype=grad_accum_dtype),
            param_spec_tree=specs)
        as_opt = lambda tree: jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, opt_dt), tree)
        opt_sds = {"m": as_opt(params_sds), "v": as_opt(params_sds),
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        opt_sh = {"m": params_sh, "v": params_sh, "step": _repl(mesh)}
        metrics_sh = {k: _repl(mesh)
                      for k in ("loss", "aux_loss", "grad_norm", "lr")}

        def fn(params, opt_state, batch):
            with use_rules(rules):
                return train_step(params, opt_state, batch)

        return (fn, (params_sds, opt_sds, batch_sds),
                (params_sh, opt_sh, batch_sh),
                (params_sh, opt_sh, metrics_sh), (0, 1), meta)

    logits_shape = ((shape.global_batch, cfg.num_codebooks, cfg.vocab_size)
                    if cfg.family == "audio"
                    else (shape.global_batch, cfg.vocab_size))
    logits_axes = (("batch", None, "vocab") if cfg.family == "audio"
                   else ("batch", "vocab"))

    if shape.kind == "prefill":
        def fn(params, batch):
            with use_rules(rules):
                return M.prefill(cfg, params, batch)

        # output shardings: derive the state tree from decode_state_specs axes
        state_specs = M.decode_state_specs(cfg, shape.global_batch,
                                           shape.seq_len)
        state_sh = {k: rules.sharding(a, s.shape)
                    for k, (s, a) in state_specs.items()}
        logits_sh = rules.sharding(logits_axes, logits_shape)
        out_sh = (logits_sh, state_sh)
        return (fn, (params_sds, batch_sds), (params_sh, batch_sh),
                out_sh, (), meta)

    # decode
    state_specs = M.decode_state_specs(cfg, shape.global_batch, shape.seq_len)
    state_sds = {k: s for k, (s, _a) in state_specs.items()}
    state_sh = {k: rules.sharding(a, s.shape)
                for k, (s, a) in state_specs.items()}
    tok_sds = batch_sds["tokens"]
    tok_sh = batch_sh["tokens"]
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    logits_sh = rules.sharding(logits_axes, logits_shape)

    def fn(params, state, tokens, pos):
        with use_rules(rules):
            return M.decode_step(cfg, params, state, tokens, pos)

    return (fn, (params_sds, state_sds, tok_sds, pos_sds),
            (params_sh, state_sh, tok_sh, _repl(mesh)),
            (logits_sh, state_sh), (1,), meta)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: Optional[int] = None, seq_res: bool = False,
             overrides: Optional[Dict[str, Any]] = None,
             opt_overrides: Optional[Dict[str, Any]] = None,
             grad_accum_dtype: str = "float32",
             verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "family": cfg.family,
    }
    ok, reason = shape_applicable(shape, cfg)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = reason
        if verbose:
            print(f"[dryrun] SKIP {arch} x {shape_name}: {reason}")
        return record
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, donate, meta = build_cell(
            cfg, shape_name, mesh, microbatches=microbatches,
            seq_res=seq_res, overrides=overrides,
            opt_overrides=opt_overrides,
            grad_accum_dtype=grad_accum_dtype)
        record.update(meta)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        ma = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        record["memory"]["peak_per_device_bytes"] = (
            record["memory"]["argument_bytes"]
            + record["memory"]["temp_bytes"]
            + record["memory"]["output_bytes"]
            - record["memory"]["alias_bytes"])
        ca = compiled.cost_analysis() or {}
        record["xla_cost"] = {k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed",
                                        "transcendentals")}
        txt = compiled.as_text()
        costs = hlo_analysis.analyze(txt)
        record["hlo"] = {
            "flops": costs.flops,
            "bytes_accessed": costs.bytes_accessed,
            "collective_bytes": costs.collective_bytes,
            "collective_count": costs.collective_count,
            "total_collective_bytes": costs.total_collective_bytes,
            "dot_count": costs.dot_count,
            "while_loops": costs.while_loops[:16],
        }
        record["timing"] = {"lower_s": t_lower - t0,
                            "compile_s": t_compile - t_lower}
        record["status"] = "ok"
        if verbose:
            mem = record["memory"]
            print(f"[dryrun] OK {arch} x {shape_name} mesh={record['mesh']} "
                  f"args={mem['argument_bytes']/2**30:.2f}GiB "
                  f"temp={mem['temp_bytes']/2**30:.2f}GiB "
                  f"flops={costs.flops:.3e} "
                  f"coll={costs.total_collective_bytes:.3e}B "
                  f"(lower {record['timing']['lower_s']:.1f}s, "
                  f"compile {record['timing']['compile_s']:.1f}s)")
            print(f"  memory_analysis: {ma}")
            print(f"  cost_analysis(flops)={ca.get('flops')} "
                  f"bytes={ca.get('bytes accessed')}")
    except Exception as e:  # a failure here is a bug in the system
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] FAIL {arch} x {shape_name}: {record['error']}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--seq-res", action="store_true",
                    help="shard the residual stream's seq dim over 'model'")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides k=v (e.g. remat=False)")
    ap.add_argument("--opt-override", action="append", default=[],
                    help="optimizer overrides k=v (e.g. state_dtype=bfloat16)")
    ap.add_argument("--grad-accum-dtype", default="float32")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [canonical(a) for a in
                                                 args.arch.split(",")]
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    overrides: Dict[str, Any] = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            overrides[k] = json.loads(v)
        except json.JSONDecodeError:
            overrides[k] = v
    opt_overrides: Dict[str, Any] = {}
    for ov in args.opt_override:
        k, v = ov.split("=", 1)
        try:
            opt_overrides[k] = json.loads(v)
        except json.JSONDecodeError:
            opt_overrides[k] = v

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp,
                               microbatches=args.microbatches,
                               seq_res=args.seq_res,
                               overrides=overrides or None,
                               opt_overrides=opt_overrides or None,
                               grad_accum_dtype=args.grad_accum_dtype)
                mesh_tag = "multi" if mp else "single"
                path = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_tag}__{args.tag}.json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "failed":
                    failures += 1
    if failures:
        raise SystemExit(f"{failures} dry-run cells FAILED")
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()
