"""Production mesh definition (per assignment).

Defined as a FUNCTION so importing this module never touches jax device state;
the dry-run sets XLA_FLAGS for 512 host devices before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Single-device (or tiny) mesh for CPU smoke tests and examples."""
    n = len(jax.devices())
    data = max(1, n // model)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
