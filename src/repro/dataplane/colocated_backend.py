"""`colocated` backend: the in-rank 'Local' baseline behind the facade.

Preprocessing happens on the trainer node, so there is no transport: the
"writer" is the worker-pool lifecycle (``__enter__`` starts the threads,
``__exit__`` stops them; ``inject_crash`` models the paper's no-failure-
isolation property), and the reader assembles one global batch's worth of
preprocessed sample indices from the shared bounded queue.

Batches carry the preprocessed sample indices as an int32 payload; ``version``
is always -1 (there is no durable control plane — which is precisely the
baseline's limitation). ``Checkpoint("colocated", -1, step)`` records the step
counter only: the queue is volatile, so restore repositions the counter but
cannot replay data (the facade makes the consistency gap explicit rather than
papering over it).
"""
from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from repro.data.colocated import ColocatedConfig, ColocatedPipeline
from repro.dataplane._base import SessionBase
from repro.dataplane.types import (Batch, Checkpoint, Topology,
                                   UnsupportedOperation)


class ColocatedWriter:
    """Worker-pool lifecycle handle (no per-batch writes: samples are produced
    by the in-process preprocessing threads)."""

    def __init__(self, pipeline: ColocatedPipeline):
        self.pipeline = pipeline
        self.recovered_offset = 0

    def __enter__(self) -> "ColocatedWriter":
        self.pipeline.start()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.pipeline.stop()
        return False

    def write(self, slices=None, *, uniform_slice_bytes=None,
              num_samples: int = 0, token_count: int = 0) -> Optional[int]:
        raise UnsupportedOperation(
            "colocated preprocessing is push-based (in-process worker "
            "threads); there is no explicit batch write")

    def write_tokens(self, tokens) -> List[int]:
        raise UnsupportedOperation(
            "colocated preprocessing is push-based; there is no explicit "
            "token feed")

    def flush(self) -> bool:
        return True

    def inject_crash(self) -> None:
        """Kill the worker pool: readers stall (no failure isolation)."""
        self.pipeline.inject_crash()


class ColocatedBatchReader:
    """Trainer-side reader: one global batch's worth of queue items."""

    def __init__(self, pipeline: ColocatedPipeline, topology: Topology):
        self.pipeline = pipeline
        self.topology = topology
        self.step = 0

    def next_batch(self, timeout_s: Optional[float] = None) -> Batch:
        items = self.pipeline.next_batch(timeout_s=timeout_s)
        step = self.step
        self.step += 1
        payload = np.asarray(items, dtype=np.int32).tobytes()
        return Batch(payload=payload, step=step, version=-1, dp_rank=0,
                     cp_rank=0,
                     array=np.asarray(items, dtype=np.int32)[None, :])

    def checkpoint(self) -> Checkpoint:
        return Checkpoint("colocated", version=-1, step=self.step,
                          topology=(self.topology.dp, self.topology.cp))

    def restore(self, ckpt: "Checkpoint | str") -> None:
        ckpt = Checkpoint.coerce(ckpt)
        if ckpt.backend != "colocated":
            raise ValueError(f"cannot restore a {ckpt.backend!r} checkpoint "
                             f"on a colocated reader")
        here = (self.topology.dp, self.topology.cp)
        if ckpt.topology is not None and tuple(ckpt.topology) != here:
            # the queue is per-node and volatile: a step counter from a
            # different mesh shape has no meaning here, so refuse loudly
            raise UnsupportedOperation(
                f"colocated backend cannot restore a checkpoint captured at "
                f"dp={ckpt.topology[0]} cp={ckpt.topology[1]} onto a "
                f"dp={here[0]} cp={here[1]} reader: the in-rank pipeline has "
                f"no topology remap. Factor DP resize is supported only by "
                f"the tgb backend's elastic restore path "
                f"(TGBBatchReader.restore / TrainSession.resume)")
        # volatile queue: the counter moves but past batches are gone — the
        # baseline cannot replay (the paper's consistency argument)
        self.step = ckpt.step

    def close(self) -> None:
        pass

    @property
    def stats(self):
        return self.pipeline.stats


class ColocatedSession(SessionBase):
    backend = "colocated"

    def __init__(self, target, topology: Topology, *,
                 namespace: str = "runs/dataplane",
                 resume: "Checkpoint | str | None" = None,
                 config: Optional[ColocatedConfig] = None,
                 preprocess_cost_s: Optional[Callable[[int], float]] = None,
                 batch_cpu_items: Optional[int] = None, clock=None):
        """``target`` may be an existing ``ColocatedPipeline``, a Clock, or
        None (a pipeline is built from ``config``/``preprocess_cost_s``)."""
        self.topology = topology
        self.namespace = namespace
        if isinstance(target, ColocatedPipeline):
            self.pipeline = target
        else:
            self.pipeline = ColocatedPipeline(
                config or ColocatedConfig(),
                preprocess_cost_s or (lambda i: 0.0),
                batch_cpu_items or topology.global_batch or topology.dp,
                clock=clock if clock is not None else target)
        self._resume = Checkpoint.coerce(resume)

    @property
    def slowdown(self) -> float:
        """The node's oversubscription factor (the contention tax every
        host-side operation — including the GPU step's host work — pays)."""
        return self.pipeline._slowdown()

    def writer(self, writer_id: str = "local-workers",
               **_opts) -> ColocatedWriter:
        return ColocatedWriter(self.pipeline)

    def reader(self, dp_rank: int = 0, cp_rank: int = 0,
               **_opts) -> ColocatedBatchReader:
        # every rank on the node shares the one queue; the facade models the
        # node-level pipeline, so readers are fungible
        r = ColocatedBatchReader(self.pipeline, self.topology)
        if self._resume is not None:
            r.restore(self._resume)
        return r

    def close(self) -> None:
        self.pipeline.stop()


def _factory(target, topology, **opts) -> ColocatedSession:
    return ColocatedSession(target, topology, **opts)
