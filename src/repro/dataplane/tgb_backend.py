"""`tgb` backend: the paper's object-store-native data plane.

Maps the facade onto the BatchWeave clients:

  writer  -> ``repro.core.Producer``  (TGB materialization + DAC-gated
             conditional-put manifest commits; ``__enter__`` recovers the
             durable stream offset, ``__exit__`` finalizes)
  reader  -> ``repro.core.Consumer``  (per-rank range reads, footer cache,
             prefetch, topology remap)
  Checkpoint("tgb", V, S) -> the consumer cursor <V, S>

The session additionally exposes the lifecycle half of the paper:
``save_watermark`` (rank checkpoints publish W_i) and ``reclaim`` (trim
everything below W_global).
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.consumer import (Consumer, MeshPosition,
                                 convert_logical_step, floor_to_data_step)
from repro.core.dac import CommitPolicy
from repro.core.lifecycle import Reclaimer, Watermark, write_watermark
from repro.core.manifest import ManifestStore, open_manifest_store
from repro.core.objectstore import IOPool, Namespace, ObjectStore
from repro.core.producer import Producer
from repro.core.resilience import wrap_store
from repro.dataplane._base import PackingWriterMixin, SessionBase
from repro.dataplane.types import (Batch, Checkpoint, Topology,
                                   UnsupportedOperation)


class TGBWriter(PackingWriterMixin):
    """Context-managed producer: recover on enter, finalize on clean exit."""

    def __init__(self, ns: Namespace, topology: Topology, writer_id: str,
                 policy: Optional[CommitPolicy] = None,
                 max_lag: Optional[int] = None,
                 pipeline_commits: bool = False,
                 io_pool: Optional[IOPool] = None,
                 obs_snap_interval_s: Optional[float] = None,
                 spill_limit: Optional[int] = None,
                 manifests: Optional[ManifestStore] = None):
        self.topology = topology
        self.writer_id = writer_id
        self.producer = Producer(ns, writer_id, dp=topology.dp, cp=topology.cp,
                                 policy=policy,
                                 manifests=manifests or open_manifest_store(ns),
                                 max_lag=max_lag,
                                 pipeline_commits=pipeline_commits,
                                 io_pool=io_pool,
                                 obs_snap_interval_s=obs_snap_interval_s,
                                 spill_limit=spill_limit)
        self.recovered_offset = 0

    # -- lifecycle -----------------------------------------------------------
    def __enter__(self) -> "TGBWriter":
        self.recovered_offset = self.producer.recover()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self.producer.finalize()
        return False

    # -- writes --------------------------------------------------------------
    def write(self, slices=None, *, uniform_slice_bytes=None,
              num_samples: int = 0, token_count: int = 0) -> int:
        desc = self.producer.write_tgb(
            slice_payloads=slices, uniform_slice_bytes=uniform_slice_bytes,
            num_samples=num_samples, token_count=token_count)
        self.producer.maybe_commit()  # cadence-gated by the commit policy
        return desc.producer_seq

    def flush(self) -> bool:
        return self.producer.maybe_commit(force=True)

    def seek(self, offset: int) -> None:
        """Deterministic-replay support: rewind the stream offset. Already
        committed offsets are deduplicated by the manifest commit protocol, so
        replaying from 0 after a crash is exactly-once by construction."""
        self.producer.next_offset = offset
        self.producer.pending = []

    @property
    def lag_exceeded(self) -> bool:
        return self.producer.lag_exceeded()

    @property
    def stats(self):
        return self.producer.stats


class TGBBatchReader:
    """Facade reader over the per-rank range-read consumer."""

    def __init__(self, ns: Namespace, topology: Topology, dp_rank: int,
                 cp_rank: int, prefetch_depth: int = 4,
                 dense_read: bool = False, verify_crc: bool = True,
                 io_pool: Optional[IOPool] = None,
                 resume: "Checkpoint | str | None" = None,
                 stats_instance: Optional[str] = None,
                 obs_snap_interval_s: Optional[float] = None,
                 manifests: Optional[ManifestStore] = None):
        self.topology = topology
        self.consumer = Consumer(
            ns, MeshPosition(dp_rank, cp_rank, topology.dp, topology.cp),
            manifests=manifests,
            prefetch_depth=prefetch_depth, dense_read=dense_read,
            verify_crc=verify_crc, io_pool=io_pool,
            stats_instance=stats_instance,
            obs_snap_interval_s=obs_snap_interval_s)
        self.dp_rank, self.cp_rank = dp_rank, cp_rank
        ckpt = Checkpoint.coerce(resume)
        if ckpt is not None:
            self.restore(ckpt)

    def next_batch(self, timeout_s: Optional[float] = None) -> Batch:
        step = self.consumer.step
        payload = self.consumer.next_batch(timeout_s=timeout_s)
        return Batch.build(payload, step=step,
                           version=self.consumer.view.version,
                           dp_rank=self.dp_rank, cp_rank=self.cp_rank,
                           topology=self.topology)

    def checkpoint(self) -> Checkpoint:
        v, s = self.consumer.cursor
        return Checkpoint("tgb", version=v, step=s,
                          topology=(self.topology.dp, self.topology.cp),
                          data_dp=self._data_dp())

    def _data_dp(self) -> int:
        """The materialized TGB layout's DP degree (falls back to the
        consuming topology before the first manifest is visible)."""
        if self.consumer.view.tgbs:
            return self.consumer.view.tgbs[0].dp
        return self.topology.dp

    def restore(self, ckpt: "Checkpoint | str") -> None:
        """Resume from a captured cursor — including one captured on a mesh
        whose DP degree differs from this reader's by an integer factor.

        The cursor's logical step is converted through the slice position
        (``step * dp_capture / dp_here``, exact); the per-slice remap itself
        happens inside the core consumer against the *materialized* layout,
        so no data is rewritten. Misaligned or non-integer-factor resizes
        raise ``UnsupportedOperation``.
        """
        ckpt = Checkpoint.coerce(ckpt)
        if ckpt.backend != "tgb":
            raise ValueError(f"cannot restore a {ckpt.backend!r} checkpoint "
                             f"on a tgb reader")
        if ckpt.composite:
            raise ValueError("composite multi-stream checkpoint cannot be "
                             "restored on a single-stream reader (open the "
                             "session with streams={...})")
        step = ckpt.step
        if ckpt.topology is not None:
            # CP changes never move the step cursor (token chunks live inside
            # a step); only the DP degree rescales logical steps.
            cap_dp = ckpt.topology[0]
            if cap_dp != self.topology.dp:
                try:
                    step = convert_logical_step(ckpt.step, cap_dp,
                                                self.topology.dp)
                except ValueError as e:
                    raise UnsupportedOperation(
                        f"cannot restore a dp={cap_dp} checkpoint on a "
                        f"dp={self.topology.dp} reader: {e}. Supported "
                        f"elastic path: integer-factor DP resize with the "
                        f"checkpoint on a global-batch boundary of the new "
                        f"degree") from e
        self.consumer.restore_cursor(ckpt.version, step)

    def poll(self) -> bool:
        """Probe for newly published batches; True if the view advanced."""
        return self.consumer.poll()

    @property
    def published_steps(self) -> int:
        """Global batches currently visible to this reader (backlog probe)."""
        return self.consumer.view.total_steps

    def start_prefetch(self) -> None:
        self.consumer.start_prefetch()

    def stop_prefetch(self) -> None:
        self.consumer.stop_prefetch()

    def close(self) -> None:
        self.consumer.stop_prefetch()

    @property
    def stats(self):
        return self.consumer.stats


class TGBSession(SessionBase):
    backend = "tgb"

    def __init__(self, store: ObjectStore, topology: Topology, *,
                 namespace: str = "runs/dataplane",
                 resume: "Checkpoint | str | None" = None,
                 expected_ranks: Optional[int] = None,
                 io_pool: Optional[IOPool] = None,
                 data_topology: Optional[Topology] = None,
                 obs_snap_interval_s: Optional[float] = None,
                 resilience=None,
                 manifest_shards: Optional[int] = None):
        if not isinstance(store, ObjectStore):
            raise TypeError(f"tgb backend needs an ObjectStore target, got "
                            f"{type(store).__name__}")
        # resilience=True / ResilienceConfig: every client this session vends
        # talks to the store through one shared ResilientStore (backoff +
        # retry budgets, throttle governor, hedged reads, circuit breaker)
        store = wrap_store(store, resilience)
        if resilience and obs_snap_interval_s is not None:
            store.attach_recorder(Namespace(store, namespace),
                                  obs_snap_interval_s)
        self.store = store
        self.topology = topology
        # the layout producers materialize TGBs at; defaults to the consuming
        # topology, but an elastically-resumed run pins it to the run's
        # original D x C so the stream layout stays uniform across restarts
        self.data_topology = data_topology or topology
        self.ns = Namespace(store, namespace)
        # one pool per session: all of this session's readers/writers share
        # its bounded in-flight request budget (None -> the process default)
        self._io_pool = io_pool
        self._resume = Checkpoint.coerce(resume)
        self._expected_ranks = expected_ranks or topology.world
        self._reclaimer: Optional[Reclaimer] = None
        self._readers: List[TGBBatchReader] = []
        # flight-recorder cadence for every client this session vends
        # (None = telemetry snapshots off; the counters still register)
        self._obs_snap_interval_s = obs_snap_interval_s
        # manifest_shards >= 2 claims a sharded manifest layout at session
        # creation (conditional put, first writer wins, immutable for the
        # run's life) so every client vended afterwards discovers it; None
        # adopts whatever the run already is (legacy single chain included)
        if manifest_shards is not None and manifest_shards > 1:
            from repro.core.manifest import write_shard_config
            write_shard_config(self.ns, manifest_shards)

    # -- clients -------------------------------------------------------------
    def writer(self, writer_id: str = "w0", *,
               policy: Optional[CommitPolicy] = None,
               max_lag: Optional[int] = None,
               pipeline_commits: bool = False,
               spill_limit: Optional[int] = None) -> TGBWriter:
        return TGBWriter(self.ns, self.data_topology, writer_id, policy=policy,
                         max_lag=max_lag, pipeline_commits=pipeline_commits,
                         io_pool=self._io_pool,
                         obs_snap_interval_s=self._obs_snap_interval_s,
                         spill_limit=spill_limit)

    def reader(self, dp_rank: int = 0, cp_rank: int = 0, *,
               prefetch_depth: int = 4, dense_read: bool = False,
               verify_crc: bool = True,
               resume: "Checkpoint | str | None" = None) -> TGBBatchReader:
        r = TGBBatchReader(self.ns, self.topology, dp_rank, cp_rank,
                           prefetch_depth=prefetch_depth,
                           dense_read=dense_read, verify_crc=verify_crc,
                           io_pool=self._io_pool,
                           resume=resume if resume is not None
                           else self._resume,
                           obs_snap_interval_s=self._obs_snap_interval_s)
        self._readers.append(r)
        return r

    # -- lifecycle -----------------------------------------------------------
    def save_watermark(self, rank: int, ckpt: "Checkpoint | str") -> None:
        ckpt = Checkpoint.coerce(ckpt)
        if ckpt.composite:
            raise ValueError(
                "composite multi-stream checkpoint cannot be used as a "
                "single-stream watermark (its step is the global mixed step; "
                "use the multi-stream session's save_watermark)")
        # Watermarks gate TGB deletion, so their step must be in the
        # *materialized* layout's units. A token captured on a resized mesh
        # carries its capture topology; convert (flooring is conservative —
        # it can only under-trim).
        step = ckpt.step
        if ckpt.topology is not None and ckpt.data_dp:
            step = floor_to_data_step(ckpt.step, ckpt.topology[0],
                                      ckpt.data_dp)
        write_watermark(self.ns, rank,
                        Watermark(version=ckpt.version, step=step))

    def reclaim(self) -> int:
        """One watermark-driven reclamation cycle; returns TGBs deleted so far."""
        if self._reclaimer is None:
            self._reclaimer = Reclaimer(self.ns,
                                        expected_ranks=self._expected_ranks)
        self._reclaimer.run_cycle()
        return self._reclaimer.stats.tgbs_deleted

    @property
    def reclaim_stats(self):
        if self._reclaimer is None:
            self._reclaimer = Reclaimer(self.ns,
                                        expected_ranks=self._expected_ranks)
        return self._reclaimer.stats

    def manifest_view(self):
        """Latest committed DatasetView (introspection/debugging)."""
        m = open_manifest_store(self.ns)
        return m.load_view(m.latest_version())

    def close(self) -> None:
        for r in self._readers:
            r.close()
        self._readers.clear()


def _factory(target, topology, **opts) -> TGBSession:
    return TGBSession(target, topology, **opts)
