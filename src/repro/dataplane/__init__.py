"""Unified DataPlane session API with pluggable backends.

One consistent batch-level abstraction over three interchangeable transports::

    from repro.dataplane import Topology, open_dataplane

    session = open_dataplane(store, Topology(dp=2, cp=2), backend="tgb",
                             namespace="runs/myjob")
    with session.writer("worker0") as w:        # recover() on enter
        w.write(slice_payloads)                  # -> stream offset
    # ... writer finalize() drains pending commits on clean exit

    reader = session.reader(dp_rank=0, cp_rank=0)
    batch = reader.next_batch(timeout_s=5)       # -> Batch (raises BatchTimeout)
    token = reader.checkpoint().encode()         # opaque exactly-once cursor
    session2 = open_dataplane(store, topo, backend="tgb", resume=token)

Facade concept -> paper term (BatchWeave, arXiv 2026):

  ``Batch``                one rank's (d, c) slice of a **TGB** (Training
                           Global Batch, §3.1) — the immutable, batch-level
                           unit both producers and consumers speak. ``step``
                           is the global step index S; ``version`` is the
                           manifest version V it became visible in.
  ``BatchWriter``          a producer client: stage-1 TGB materialization +
                           stage-2 manifest commit, cadence-governed by the
                           **DAC** policy (Deadline-Aware Commit, Alg. 1).
                           The context manager owns §5.3 exactly-once
                           recovery (enter) and Alg. 1 finalization (exit).
  ``BatchReader``          a consumer client: the paper's cursor ``<V, S>``
                           with per-rank targeted range reads, prefetch, and
                           §4.1 topology remap.
  ``Checkpoint``           the opaque ``<V, S>`` cursor token; saving it with
                           a model checkpoint and passing it back via
                           ``resume=`` is the exactly-once restore flow.
  ``save_watermark``       publish a rank's **watermark** W_i after a model
                           checkpoint; ``reclaim`` trims everything below
                           W_global = min_i(W_i) (§6 lifecycle).
  ``backend="tgb"``        the object-store-native data plane (the paper's
                           system); ``"mq"`` the strict-TGB Kafka baseline
                           (§7.1); ``"colocated"`` the in-rank Local baseline
                           (§2.2). New transports plug in via
                           ``register_backend`` without touching call sites.
  ``streams={...}``        beyond-paper multi-stream mode (tgb only): N named
                           TGB streams, each an independent manifest chain
                           under ``<run>/streams/<name>``, deterministically
                           interleaved by weight (``repro.streams``). Readers
                           become MixedReaders and checkpoints become
                           composite (per-stream cursors + mix position).
"""
from repro.core.errors import BatchTimeout
from repro.dataplane.colocated_backend import (ColocatedBatchReader,
                                               ColocatedSession,
                                               ColocatedWriter)
from repro.dataplane.colocated_backend import _factory as _colocated_factory
from repro.dataplane.mq_backend import MQBatchReader, MQSession, MQWriter
from repro.dataplane.mq_backend import _factory as _mq_factory
from repro.dataplane.registry import (available_backends, backend_factory,
                                      register_backend)
from repro.dataplane.session import open_dataplane
from repro.dataplane.tgb_backend import TGBBatchReader, TGBSession, TGBWriter
from repro.dataplane.tgb_backend import _factory as _tgb_factory
from repro.dataplane.types import (Batch, BatchReader, BatchWriter, Checkpoint,
                                   DataPlaneSession, Topology,
                                   UnsupportedOperation)

for _name, _f in (("tgb", _tgb_factory), ("mq", _mq_factory),
                  ("colocated", _colocated_factory)):
    register_backend(_name, _f, overwrite=True)

__all__ = [
    "Batch", "BatchReader", "BatchTimeout", "BatchWriter", "Checkpoint",
    "ColocatedBatchReader", "ColocatedSession", "ColocatedWriter",
    "DataPlaneSession", "MQBatchReader", "MQSession", "MQWriter",
    "TGBBatchReader", "TGBSession", "TGBWriter", "Topology",
    "UnsupportedOperation", "available_backends", "backend_factory",
    "open_dataplane", "register_backend",
]
