"""Facade value types and client protocols.

Every backend speaks the same three nouns:

  * ``Topology``   — the consuming mesh's data-relevant shape (DP x CP) plus,
    optionally, the token-grid shape (``global_batch`` x ``seq_len``) that lets
    readers decode slice payloads into ``np.ndarray`` shards,
  * ``Batch``      — one rank's shard of one global batch, with its ``step``
    and manifest ``version`` attached,
  * ``Checkpoint`` — an opaque, string-encodable cursor token that round-trips
    the exactly-once save/restore flow across backends.
"""
from __future__ import annotations

import base64
from dataclasses import dataclass
from typing import (Dict, List, Mapping, Optional, Protocol, Tuple,
                    runtime_checkable)

import msgpack
import numpy as np

from repro.core.errors import BatchTimeout

__all__ = [
    "Batch", "BatchReader", "BatchTimeout", "BatchWriter", "Checkpoint",
    "DataPlaneSession", "Topology", "UnsupportedOperation",
]


class UnsupportedOperation(RuntimeError):
    """The selected backend cannot perform this facade operation."""


@dataclass(frozen=True)
class Topology:
    """Data-relevant shape of the consuming mesh.

    ``dp`` x ``cp`` determines how each global batch is sliced (TP/PP ranks of
    one (d, c) group share a slice and simply reuse the same reader
    coordinates). When ``global_batch`` and ``seq_len`` are given, readers
    decode token-slice payloads into ``(global_batch/dp, seq_len/cp)`` int32
    arrays; otherwise batches carry raw bytes only.

    Attributes:
      dp: data-parallel degree (number of batch slices per global batch).
      cp: context-parallel degree (token-chunk slices per DP replica).
      global_batch: optional samples per global batch; must divide by ``dp``.
      seq_len: optional tokens per sample; must divide by ``cp``.

    Example::

        topo = Topology(dp=4, cp=2, global_batch=64, seq_len=4096)
        topo.world              # 8 (d, c) mesh positions
        topo.samples_per_slice  # 16 samples per DP slice
        topo.seq_per_rank       # 2048 tokens per CP chunk
    """

    dp: int = 1
    cp: int = 1
    global_batch: Optional[int] = None
    seq_len: Optional[int] = None

    def __post_init__(self):
        if self.dp < 1 or self.cp < 1:
            raise ValueError(f"dp/cp must be >= 1, got {self.dp}x{self.cp}")
        if self.global_batch is not None and self.global_batch % self.dp:
            raise ValueError(
                f"global_batch {self.global_batch} % dp {self.dp} != 0")
        if self.seq_len is not None and self.seq_len % self.cp:
            raise ValueError(f"seq_len {self.seq_len} % cp {self.cp} != 0")

    @property
    def world(self) -> int:
        return self.dp * self.cp

    @property
    def decodable(self) -> bool:
        return self.global_batch is not None and self.seq_len is not None

    @property
    def samples_per_slice(self) -> int:
        if self.global_batch is None:
            raise ValueError("Topology has no global_batch")
        return self.global_batch // self.dp

    @property
    def seq_per_rank(self) -> int:
        if self.seq_len is None:
            raise ValueError("Topology has no seq_len")
        return self.seq_len // self.cp


@dataclass(frozen=True)
class Batch:
    """One rank's shard of one global batch.

    ``payload`` is always present (the raw slice bytes). ``array`` is the
    decoded ``(samples_per_slice, seq_per_rank)`` int32 token grid when the
    session's Topology carries the grid shape and the payload matches it.
    ``version`` is the manifest version the batch became visible in (-1 for
    backends without a versioned control plane). ``stream`` names the source
    stream on a multi-stream session (None on single-stream sessions), in
    which case ``step`` is the *global* mixed step and ``version`` is that
    stream's manifest version.
    """

    payload: bytes
    step: int
    version: int
    dp_rank: int
    cp_rank: int
    array: Optional[np.ndarray] = None
    stream: Optional[str] = None

    @property
    def tokens(self) -> np.ndarray:
        if self.array is None:
            raise ValueError(
                "Batch payload is not a decodable token grid (open the "
                "session with Topology(global_batch=..., seq_len=...))")
        return self.array

    def __len__(self) -> int:
        return len(self.payload)

    @staticmethod
    def build(payload: bytes, step: int, version: int, dp_rank: int,
              cp_rank: int, topology: Topology,
              stream: Optional[str] = None) -> "Batch":
        arr = None
        if topology.decodable:
            want = topology.samples_per_slice * topology.seq_per_rank * 4
            if len(payload) == want:
                arr = np.frombuffer(payload, dtype=np.int32).reshape(
                    topology.samples_per_slice, topology.seq_per_rank)
        return Batch(payload=payload, step=step, version=version,
                     dp_rank=dp_rank, cp_rank=cp_rank, array=arr,
                     stream=stream)


#: Current token schema tag. v1 (``bwck1``) predates the RunManifest /
#: elastic-restore work and carried no capture topology; v2 adds it.
_CKPT_MAGIC = "bwck2"
_RETIRED_MAGICS = ("bwck1",)


@dataclass(frozen=True)
class Checkpoint:
    """Opaque exactly-once cursor token.

    For the tgb backend this is the paper's ``<V, S>`` consumer cursor
    (manifest version + next global step); for mq it is the next broker
    offset; for colocated it is the step counter. ``encode()`` yields a
    printable token safe to embed in a model checkpoint; ``open_dataplane``
    and ``reader.restore`` accept either the object or the encoded string.

    On a multi-stream session the token is *composite*: ``step`` is the global
    mixed step (the mix position — the schedule itself is recomputed from
    ``(weights, seed)``, never stored) and ``streams`` carries every stream's
    ``<V, S>`` cursor as ``(name, version, step)`` triples sorted by name.
    Single-stream tokens have ``streams=None`` and decode unchanged.

    ``topology`` records the capturing mesh's ``(dp, cp)``: the tgb backend
    uses it to remap the cursor onto a factor-resized mesh on restore, and
    the mq/colocated backends use it to *refuse* such a restore loudly
    instead of silently misreading slices. ``data_dp`` is the materialized
    TGB layout's DP degree at capture (the invariant unit elastic restores
    convert through) and ``mix_pos`` the composite token's mix position in
    those materialized units. All three are ``None`` on hand-built tokens,
    which restore positionally exactly as before.

    The wire format is versioned by a schema tag: tokens from a retired
    schema decode with a clear "re-checkpoint" error instead of a field
    ``KeyError`` deep inside a restore.

    Example — the save/restore round trip::

        token = reader.checkpoint().encode()       # str, store it anywhere
        ...                                        # crash, restart, rollback
        ckpt = Checkpoint.decode(token)            # or pass the str directly
        reader.restore(ckpt)                       # resumes exactly-once

    ``Checkpoint.coerce`` accepts a ``Checkpoint``, an encoded token string,
    or ``None`` — every facade entry point that takes a cursor uses it, so
    callers never need to decode by hand.
    """

    backend: str
    version: int
    step: int
    streams: Optional[Tuple[Tuple[str, int, int], ...]] = None
    topology: Optional[Tuple[int, int]] = None  # (dp, cp) at capture
    data_dp: Optional[int] = None   # materialized TGB layout DP at capture
    mix_pos: Optional[int] = None   # composite: mix position in data units

    @property
    def composite(self) -> bool:
        return self.streams is not None

    def stream_cursor(self, name: str) -> Tuple[int, int]:
        """(version, step) cursor of one named stream in a composite token."""
        for sname, v, s in self.streams or ():
            if sname == name:
                return (v, s)
        raise KeyError(f"checkpoint has no cursor for stream {name!r}")

    def encode(self) -> str:
        doc = {"m": _CKPT_MAGIC, "b": self.backend,
               "v": self.version, "s": self.step}
        if self.streams is not None:
            doc["st"] = [list(row) for row in self.streams]
        if self.topology is not None:
            doc["tp"] = list(self.topology)
        if self.data_dp is not None:
            doc["dd"] = self.data_dp
        if self.mix_pos is not None:
            doc["mu"] = self.mix_pos
        raw = msgpack.packb(doc)
        return base64.urlsafe_b64encode(raw).decode("ascii")

    @staticmethod
    def decode(token: str) -> "Checkpoint":
        try:
            d = msgpack.unpackb(base64.urlsafe_b64decode(token.encode("ascii")),
                                raw=False)
        except Exception as e:
            raise ValueError(
                f"not a dataplane Checkpoint token: {token!r}") from e
        magic = d.get("m") if isinstance(d, dict) else None
        if magic in _RETIRED_MAGICS:
            raise ValueError(
                f"checkpoint token uses the retired {magic!r} schema "
                f"(pre-RunManifest, no capture topology); current schema is "
                f"{_CKPT_MAGIC!r} — re-checkpoint the run to mint a "
                f"restorable token")
        if magic != _CKPT_MAGIC:
            raise ValueError(f"not a dataplane Checkpoint token: {token!r}")
        try:
            streams = None
            if d.get("st") is not None:
                streams = tuple(tuple(row) for row in d["st"])
            topology = tuple(d["tp"]) if d.get("tp") is not None else None
            return Checkpoint(backend=d["b"], version=d["v"], step=d["s"],
                              streams=streams, topology=topology,
                              data_dp=d.get("dd"), mix_pos=d.get("mu"))
        except Exception as e:
            raise ValueError(f"not a dataplane Checkpoint token: {token!r}") from e

    @staticmethod
    def coerce(obj: "Checkpoint | str | None") -> "Optional[Checkpoint]":
        if obj is None or isinstance(obj, Checkpoint):
            return obj
        if isinstance(obj, str):
            return Checkpoint.decode(obj)
        raise TypeError(f"expected Checkpoint or token string, got {type(obj)}")

    def as_tuple(self) -> Tuple[int, int]:
        return (self.version, self.step)


# ---------------------------------------------------------------------------
# Client protocols (structural — backends implement these shapes)
# ---------------------------------------------------------------------------

@runtime_checkable
class BatchReader(Protocol):
    """One (dp_rank, cp_rank) position's view of the batch stream.

    Structural protocol: every backend reader (``TGBBatchReader``,
    ``MQBatchReader``, ``ColocatedBatchReader``, ``MixedReader``) satisfies
    it, so training loops are written once against these four methods. A
    reader is single-threaded by contract — one reader per rank, ranks never
    coordinate (the manifest is the only shared state).
    """

    def next_batch(self, timeout_s: Optional[float] = None) -> Batch:
        """Blocking read of the next global batch's shard for this rank.
        Raises ``BatchTimeout`` if it is not available in time."""
        ...

    def checkpoint(self) -> Checkpoint:
        """Cursor token for the NEXT batch this reader would return."""
        ...

    def restore(self, ckpt: "Checkpoint | str") -> None:
        """Resume from a previously captured Checkpoint."""
        ...

    def close(self) -> None:
        ...


@runtime_checkable
class BatchWriter(Protocol):
    """One producer's write handle. Context-manager lifecycle: ``__enter__``
    recovers the durable stream offset (exactly-once restart), ``__exit__``
    finalizes (drains uncommitted batches) on clean exit.

    The restart contract: re-create the writer with the **same** writer id
    after a crash and re-enter the context — offsets the dead incarnation
    already committed are deduplicated by the manifest's producer state map,
    so replaying the input stream from the recovered offset is exactly-once
    by construction (rehearsed by ``repro.chaos``; see
    ``docs/OPERATIONS.md``).
    """

    def write(self, slices: Optional[Mapping[Tuple[int, int], bytes]] = None,
              *, uniform_slice_bytes: Optional[int] = None,
              num_samples: int = 0, token_count: int = 0) -> Optional[int]:
        """Publish one global batch (all D x C slices). Returns the stream
        offset it was written at (None if the backend dropped it)."""
        ...

    def write_tokens(self, tokens: np.ndarray) -> List[int]:
        """Feed a token stream; packs and publishes every completed global
        batch. Requires a decodable Topology. Returns offsets published."""
        ...

    def flush(self) -> bool:
        """Force a commit attempt of any pending batches."""
        ...

    def __enter__(self) -> "BatchWriter":
        ...

    def __exit__(self, exc_type, exc, tb) -> bool:
        ...


@runtime_checkable
class DataPlaneSession(Protocol):
    """A handle on one training run's data plane."""

    backend: str
    topology: Topology

    def writer(self, writer_id: str = "w0", **opts) -> BatchWriter:
        ...

    def reader(self, dp_rank: int = 0, cp_rank: int = 0, **opts) -> BatchReader:
        ...

    def close(self) -> None:
        ...
