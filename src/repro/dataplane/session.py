"""``open_dataplane`` — the single entry point to every data-plane backend."""
from __future__ import annotations

from typing import Mapping, Optional

from repro.dataplane.registry import backend_factory
from repro.dataplane.types import (Checkpoint, DataPlaneSession, Topology,
                                   UnsupportedOperation)


def open_dataplane(target, topology: Topology, backend: str = "tgb", *,
                   namespace: str = "runs/dataplane",
                   resume: "Checkpoint | str | None" = None,
                   streams: Optional[Mapping[str, float]] = None,
                   mix_seed: int = 0,
                   **backend_opts) -> DataPlaneSession:
    """Open a data-plane session over an interchangeable backend.

    Args:
      target: the transport substrate — an ``ObjectStore`` for ``tgb``, a
        ``KafkaSimBroker`` (or None to build one) for ``mq``, a
        ``ColocatedPipeline``/Clock/None for ``colocated``. Custom backends
        define their own target type.
      topology: the consuming mesh's ``Topology`` (DP x CP, optionally the
        global-batch token grid so readers decode arrays).
      backend: registered backend name (see ``available_backends()``).
      namespace: run prefix on the substrate (a fresh namespace is all a new
        job needs).
      resume: a ``Checkpoint`` (or its encoded token) to restore every reader
        vended by this session — the exactly-once cursor restore flow. With
        ``streams`` this must be a composite token (a MixedReader
        checkpoint).
      streams: optional ``{name: weight}`` map of named TGB streams. When
        given (tgb backend only) the session is multi-stream: ``writer(...,
        stream=<name>)`` vends per-stream producers and ``reader(...)``
        returns one MixedReader whose step sequence deterministically
        interleaves the streams by weight.
      mix_seed: seed of the deterministic mixing schedule (only meaningful
        with ``streams``; the schedule is a pure function of
        ``(weights, mix_seed, step)``).
      **backend_opts: forwarded to the backend session factory.

    Returns a session vending ``writer()`` / ``reader()`` handles that conform
    to the shared ``BatchWriter`` / ``BatchReader`` protocols.

    Raises:
      TypeError: ``topology`` is not a ``Topology`` (or ``target`` does not
        match the backend's substrate type).
      ValueError: ``resume`` token was captured on a different backend
        (cursors are not portable across transports) or is malformed, or
        ``backend`` is not a registered backend name.
      UnsupportedOperation: ``streams`` given with a non-tgb backend.

    Example::

        from repro.core import MemoryObjectStore
        from repro.dataplane import Topology, open_dataplane

        store = MemoryObjectStore()
        topo = Topology(dp=2, cp=1, global_batch=4, seq_len=16)
        session = open_dataplane(store, topo, namespace="runs/job")
        with session.writer("w0") as w:       # recover() on enter
            w.write(uniform_slice_bytes=256)  # -> stream offset 0
        batch = session.reader(dp_rank=0).next_batch(timeout_s=5)
        token = session.reader(dp_rank=1).checkpoint().encode()
        # later / elsewhere: resume every reader from the saved cursor
        session2 = open_dataplane(store, topo, namespace="runs/job",
                                  resume=token)
    """
    if not isinstance(topology, Topology):
        raise TypeError(f"topology must be a dataplane Topology, got "
                        f"{type(topology).__name__}")
    ckpt = Checkpoint.coerce(resume)
    if ckpt is not None and ckpt.backend != backend:
        raise ValueError(
            f"resume token was captured on backend {ckpt.backend!r} but this "
            f"session uses {backend!r}; cursors are not portable across "
            f"transports")
    if streams is not None:
        if backend != "tgb":
            raise UnsupportedOperation(
                f"multi-stream sessions need the object-store-native 'tgb' "
                f"backend (per-stream namespace prefixes); got {backend!r}")
        from repro.streams import MultiStreamSession

        return MultiStreamSession(target, topology, streams=streams,
                                  mix_seed=mix_seed, namespace=namespace,
                                  resume=ckpt, **backend_opts)
    factory = backend_factory(backend)
    return factory(target, topology, namespace=namespace, resume=ckpt,
                   **backend_opts)
