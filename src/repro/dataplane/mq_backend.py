"""`mq` backend: the centralized message-queue baseline behind the facade.

One broker message carries one complete TGB blob (strict-TGB mode); a reader
fetches whole messages and keeps its own (d, c) slice — the record/offset
abstraction's D x C read amplification is preserved by construction, which is
exactly what makes facade-level benchmarks apples-to-apples.

  writer  -> ``KafkaTGBProducer`` (TGBBuilder blob -> broker.append)
  reader  -> ``KafkaTGBConsumer`` (whole-message fetch + local slice)
  Checkpoint("mq", -1, offset) -> the next broker offset

The broker has no manifest, so ``version`` is always -1 and there is no
watermark/reclamation lifecycle. Exactly-once writer recovery is offset-based:
``__enter__`` records the broker's end offset as the recovery point, and a
deterministic replay from sequence 0 deduplicates every sequence below it
(exact for the single-writer-per-log deployment the strict-TGB mode models;
with interleaved writers the broker offset over-counts and recovery degrades
to at-most-once for the interleaved span — the record/offset abstraction has
no per-producer durable state to do better, which is the paper's point).
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.tgb import TGBBuilder, build_uniform_tgb
from repro.data.mq import (BrokerConfig, KafkaSimBroker, KafkaTGBConsumer,
                           KafkaTGBProducer)
from repro.dataplane._base import PackingWriterMixin, SessionBase
from repro.dataplane.types import (Batch, Checkpoint, Topology,
                                   UnsupportedOperation)


class MQWriter(PackingWriterMixin):
    """Context-managed strict-TGB publisher."""

    def __init__(self, broker: KafkaSimBroker, topology: Topology,
                 writer_id: str):
        self.broker = broker
        self.topology = topology
        self.writer_id = writer_id
        self.kp = KafkaTGBProducer(broker, instance=writer_id)
        self.next_seq = 0
        self.recovered_offset = 0

    def __enter__(self) -> "MQWriter":
        # a broker log is the durable state: resume after the last appended
        # message (no per-producer manifest offsets to recover)
        self.recovered_offset = self.broker.end_offset()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False  # appends are synchronous; nothing to drain

    def write(self, slices=None, *, uniform_slice_bytes=None,
              num_samples: int = 0, token_count: int = 0) -> Optional[int]:
        seq = self.next_seq
        if seq < self.recovered_offset:
            # exactly-once replay dedup: this sequence is already in the log
            self.next_seq = seq + 1
            return None
        tgb_id = f"{self.writer_id}-{seq:012d}"
        if slices is not None:
            b = TGBBuilder(tgb_id, self.topology.dp, self.topology.cp,
                           self.writer_id, seq, num_samples=num_samples,
                           token_count=token_count)
            for (d, c), payload in slices.items():
                b.add_slice(d, c, payload)
            blob = b.build()
        else:
            blob = build_uniform_tgb(tgb_id, self.topology.dp,
                                     self.topology.cp, self.writer_id, seq,
                                     uniform_slice_bytes or 1024,
                                     num_samples=num_samples,
                                     token_count=token_count)
        self.next_seq = seq + 1
        return self.kp.publish_tgb(blob)  # None if the broker dropped it

    def flush(self) -> bool:
        return True

    def seek(self, offset: int) -> None:
        """Rewind for deterministic replay (sequences below the recovery
        point are deduplicated by ``write``)."""
        self.next_seq = offset

    @property
    def stats(self):
        return self.kp.stats


class MQBatchReader:
    """Facade reader over the whole-message record consumer."""

    def __init__(self, broker: KafkaSimBroker, topology: Topology,
                 dp_rank: int, cp_rank: int,
                 resume: "Checkpoint | str | None" = None):
        self.topology = topology
        self.consumer = KafkaTGBConsumer(broker, dp_rank, cp_rank,
                                         topology.dp, topology.cp)
        self.dp_rank, self.cp_rank = dp_rank, cp_rank
        ckpt = Checkpoint.coerce(resume)
        if ckpt is not None:
            self.restore(ckpt)

    def next_batch(self, timeout_s: Optional[float] = None) -> Batch:
        step = self.consumer.offset
        payload = self.consumer.next_batch(timeout_s=timeout_s)
        return Batch.build(payload, step=step, version=-1,
                           dp_rank=self.dp_rank, cp_rank=self.cp_rank,
                           topology=self.topology)

    def checkpoint(self) -> Checkpoint:
        return Checkpoint("mq", version=-1, step=self.consumer.offset,
                          topology=(self.topology.dp, self.topology.cp))

    def restore(self, ckpt: "Checkpoint | str") -> None:
        ckpt = Checkpoint.coerce(ckpt)
        if ckpt.backend != "mq":
            raise ValueError(f"cannot restore a {ckpt.backend!r} checkpoint "
                             f"on an mq reader")
        here = (self.topology.dp, self.topology.cp)
        if ckpt.topology is not None and tuple(ckpt.topology) != here:
            # a broker offset has no (step, rank) -> (offset, slice) remap:
            # reinterpreting it under a different D x C silently misreads
            # slices, so refuse instead
            raise UnsupportedOperation(
                f"mq backend cannot restore a checkpoint captured at "
                f"dp={ckpt.topology[0]} cp={ckpt.topology[1]} onto a "
                f"dp={here[0]} cp={here[1]} reader: the record/offset "
                f"abstraction has no topology remap. Factor DP resize is "
                f"supported only by the tgb backend's elastic restore path "
                f"(TGBBatchReader.restore / TrainSession.resume)")
        self.consumer.offset = ckpt.step

    def close(self) -> None:
        pass

    @property
    def stats(self):
        return self.consumer.stats


class MQSession(SessionBase):
    backend = "mq"

    def __init__(self, broker: Optional[KafkaSimBroker], topology: Topology, *,
                 namespace: str = "runs/dataplane",
                 resume: "Checkpoint | str | None" = None,
                 broker_config: Optional[BrokerConfig] = None, clock=None):
        if broker is None:
            broker = KafkaSimBroker(broker_config or BrokerConfig(),
                                    clock=clock)
        if not isinstance(broker, KafkaSimBroker):
            raise TypeError(f"mq backend needs a KafkaSimBroker target, got "
                            f"{type(broker).__name__}")
        self.broker = broker
        self.topology = topology
        self.namespace = namespace  # informational; the broker log is global
        self._resume = Checkpoint.coerce(resume)
        self._readers: List[MQBatchReader] = []

    def writer(self, writer_id: str = "w0", **_opts) -> MQWriter:
        return MQWriter(self.broker, self.topology, writer_id)

    def reader(self, dp_rank: int = 0, cp_rank: int = 0, *,
               resume: "Checkpoint | str | None" = None,
               **_opts) -> MQBatchReader:
        r = MQBatchReader(self.broker, self.topology, dp_rank, cp_rank,
                          resume=resume if resume is not None
                          else self._resume)
        self._readers.append(r)
        return r

    def close(self) -> None:
        self._readers.clear()


def _factory(target, topology, **opts) -> MQSession:
    return MQSession(target, topology, **opts)
