"""Shared backend scaffolding: packing writer mixin + session base."""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.data.packing import GlobalBatchPacker
from repro.dataplane.types import Topology, UnsupportedOperation


class PackingWriterMixin:
    """Gives a backend writer ``write_tokens`` on top of its ``write``.

    Requires ``self.topology`` (a decodable Topology) and ``self.write``.
    """

    topology: Topology
    _packer: Optional[GlobalBatchPacker] = None

    def _ensure_packer(self) -> GlobalBatchPacker:
        if self._packer is None:
            t = self.topology
            if not t.decodable:
                raise UnsupportedOperation(
                    "write_tokens needs Topology(global_batch=..., "
                    "seq_len=...) so the writer can pack the stream")
            self._packer = GlobalBatchPacker(t.global_batch, t.seq_len,
                                             t.dp, t.cp)
        return self._packer

    def write_tokens(self, tokens: np.ndarray) -> List[int]:
        packer = self._ensure_packer()
        offsets: List[int] = []
        for batch in packer.add_tokens(np.asarray(tokens)):
            off = self.write(batch.slices, num_samples=batch.num_samples,
                             token_count=batch.token_count)
            if off is not None:
                offsets.append(off)
        return offsets

    def flush_tokens(self, pad_token: int = 0) -> Optional[int]:
        """End-of-stream: publish the packer's buffered remainder as one
        final batch padded with ``pad_token`` (None if nothing is buffered)."""
        if self._packer is None:
            return None
        batch = self._packer.flush(pad_token=pad_token)
        if batch is None:
            return None
        return self.write(batch.slices, num_samples=batch.num_samples,
                          token_count=batch.token_count)


class SessionBase:
    """Default implementations for optional session capabilities."""

    backend: str = "?"

    def save_watermark(self, rank: int, ckpt) -> None:
        raise UnsupportedOperation(
            f"backend {self.backend!r} has no checkpoint-watermark lifecycle")

    def reclaim(self) -> int:
        raise UnsupportedOperation(
            f"backend {self.backend!r} has no reclamation lifecycle")

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False

    def close(self) -> None:
        pass
