"""Backend registry: new transports plug in without touching call sites.

A backend is a factory ``(target, topology, *, namespace, resume, **opts) ->
DataPlaneSession``. The three built-ins (tgb, mq, colocated) self-register on
package import; external code can add its own (e.g. a future sharded-store
backend) via ``register_backend`` and callers reach it by name through
``open_dataplane(..., backend="mybackend")``.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

__all__ = ["available_backends", "backend_factory", "register_backend"]

_REGISTRY: Dict[str, Callable] = {}


def register_backend(name: str, factory: Callable, *,
                     overwrite: bool = False) -> None:
    """Register a session factory under ``name``."""
    if not name or not isinstance(name, str):
        raise ValueError(f"backend name must be a non-empty string: {name!r}")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[name] = factory


def backend_factory(name: str) -> Callable:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown dataplane backend {name!r}; available: "
            f"{', '.join(available_backends())}") from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
