"""Named kill/restart scenarios at the protocol points that matter.

Each scenario kills (or degrades) exactly one component at a named point in
the commit/read/reclaim protocol, recovers it the way an operator would, and
asserts the §5 guarantees: exactly-once delivery, atomic all-rank
visibility, and a clean ``fsck`` after repair. See ``harness.py`` for the
shared machinery and ``docs/OPERATIONS.md`` for the matching playbooks.

Protocol points covered:

  producer_precommit_kill        crash *before* the conditional manifest put
  producer_post_upload_kill      crash after a TGB upload, before its commit
  consumer_midstep_kill          reader dies past its last checkpoint
  mixed_reader_midstep_kill      same, across weighted multi-stream mixing
  reclaimer_midtrim_kill         reclaimer dies halfway through deletion
  cput_conflict_storm            3 producers × injected 5xx/lost-ack commits
  flaky_reads                    consumer under 5xx / short / stale reads
  trainer_midcheckpoint_kill     trainer dies between model upload and its
                                 RunManifest commit (aligned recovery)
  derive_worker_midpublish_kill  derive worker dies between publishing its
                                 outputs and committing the derive cursor
  producer_kill_obs_postmortem   killed producer diagnosed post-mortem from
                                 its flight-recorder snapshots alone
  brownout_throttle_storm        producers + consumer ride out a scripted 503
                                 SlowDown storm behind the ResilientStore
  store_outage_resume            full store outage mid-run: consumer serves
                                 prefetched TGBs, producer spills and replays
  shard_conflict_storm           6 producers × injected 5xx over a 4-shard
                                 manifest plane (rebase + shard choice +
                                 cross-shard dedup)
  compactor_midfold_kill         compactor dies between segment write and
                                 shard trims; readers dedup, repair is
                                 idempotent
"""
from __future__ import annotations

import threading

import numpy as np

from repro.core import (BrownoutPhase, Consumer, FaultPolicy,
                        FaultyObjectStore, InjectedCrash, ManifestStore,
                        MemoryObjectStore, MeshPosition, Namespace, Producer,
                        Reclaimer, ResilienceConfig, ResilientStore,
                        Watermark, write_watermark)
from repro.dataplane import Topology
from repro.run import TrainSession
from repro.chaos.harness import (CHAOS_PREFIX, ScenarioResult,
                                 assert_all_ranks_converge,
                                 assert_exactly_once, audit_and_repair,
                                 deterministic_payload, drain, fresh_ns,
                                 latest_view, make_slices, now, produce_range,
                                 reader, scenario)
from repro.ops import fsck

N_TGBS = 10


def _killed_producer_run(ns: Namespace, crash_op: str, crash_sub: str,
                         nth: int, phase: str, dp: int = 2) -> None:
    """Drive a producer into an injected crash at the named protocol point."""
    ns.store.faults.crash_on(crash_op, key_substr=crash_sub, nth=nth,
                             phase=phase)
    p = Producer(ns, "P", dp=dp, cp=1)
    p.recover()
    try:
        produce_range(p, N_TGBS)
    except InjectedCrash:
        return
    raise AssertionError(f"crash rule ({crash_op}, {crash_sub!r}, nth={nth}, "
                         f"{phase}) never fired")


def _recover_and_verify(ns: Namespace, name: str, dp: int = 2
                        ) -> ScenarioResult:
    """Shared back half of the producer-kill scenarios: replace the producer,
    resume from durable state, and check every guarantee."""
    ns.store.faults = None  # the kill happened; the replacement runs clean
    t0 = now()
    replacement = Producer(ns, "P", dp=dp, cp=1, epoch=1)
    resume = replacement.recover()
    assert resume >= 0, "recover() must yield a resumable offset"
    produce_range(replacement, N_TGBS)
    recovery_latency = now() - t0

    # exactly-once, per rank, byte-identical payloads
    consumers = [reader(ns, d, 0, dp, 1) for d in range(dp)]
    for d, cons in enumerate(consumers):
        assert_exactly_once(drain(cons, N_TGBS), "P", d, 0, N_TGBS)
    assert_all_ranks_converge(consumers)

    # the crashed incarnation's uncommitted TGB must surface as a safe
    # orphan, and the namespace must audit clean once repaired
    orphans, clean = audit_and_repair(ns)
    assert orphans >= 1, "expected the killed incarnation to leave an orphan"
    assert clean, "fsck not clean after repair"
    return ScenarioResult(name=name, passed=True,
                          steps_delivered=N_TGBS * dp,
                          recovery_latency_s=recovery_latency,
                          orphans_detected=orphans, fsck_clean_after=True)


@scenario("producer_precommit_kill")
def producer_precommit_kill(seed: int = 0) -> ScenarioResult:
    """Kill the producer right before its 3rd conditional manifest put: two
    offsets are durable, one TGB is uploaded but unpublished."""
    ns = fresh_ns()
    _killed_producer_run(ns, "cput", ".manifest", nth=3, phase="before")
    return _recover_and_verify(ns, "producer_precommit_kill")


@scenario("producer_post_upload_kill")
def producer_post_upload_kill(seed: int = 0) -> ScenarioResult:
    """Kill the producer right after its 4th TGB upload (post-upload,
    pre-manifest): the object exists but no manifest ever names it."""
    ns = fresh_ns()
    _killed_producer_run(ns, "put", "/tgb/", nth=4, phase="after")
    return _recover_and_verify(ns, "producer_post_upload_kill")


@scenario("producer_kill_obs_postmortem")
def producer_kill_obs_postmortem(seed: int = 0) -> ScenarioResult:
    """Kill a producer mid-run and diagnose it from storage alone: the
    flight recorder published a snapshot per commit attempt, so ``top``
    renders the dead incarnation's counters with no process left to ask.
    Recovery then proceeds exactly like the plain post-upload kill —
    telemetry must never perturb the data path."""
    import io

    from repro.obs.recorder import latest_snapshot
    from repro.ops.obs import component_summary, obs_summary, render_top

    ns = fresh_ns()
    # 6th TGB upload lands, then the process dies before committing it
    ns.store.faults.crash_on("put", key_substr="/tgb/", nth=6, phase="after")
    p = Producer(ns, "P", dp=2, cp=1, obs_snap_interval_s=0.0)
    comp = p.stats.metric_scope  # registry may suffix across scenarios
    p.recover()
    try:
        produce_range(p, N_TGBS)
        raise AssertionError("crash rule (put, '/tgb/', nth=6) never fired")
    except InjectedCrash:
        pass
    del p  # the incarnation is gone; only the object store remains

    # post-mortem: storage is the only witness left
    snap = latest_snapshot(ns, comp)
    assert snap is not None, "dead producer left no readable snapshot"
    written = snap["metrics"].get(f"{comp}.tgbs_written", 0)
    assert written >= 1, f"last snapshot shows no work: {snap['metrics']}"
    row = component_summary(ns, comp)
    assert row["family"] == "producer" and row["snaps"] >= 2, row
    assert "conflict_rate" in row, row
    summary = obs_summary(ns)
    assert comp in {r["component"] for r in summary["components"]}
    buf = io.StringIO()
    render_top(summary, buf)
    assert comp in buf.getvalue(), buf.getvalue()

    return _recover_and_verify(ns, "producer_kill_obs_postmortem")


@scenario("consumer_midstep_kill")
def consumer_midstep_kill(seed: int = 0) -> ScenarioResult:
    """Kill a reader two steps past its last checkpoint; a replacement
    restores the <V, S> cursor and replays the lost window byte-identically
    (exactly-once relative to checkpointed training state)."""
    ns = fresh_ns()
    p = Producer(ns, "P", dp=1, cp=1)
    produce_range(p, 12)
    cons = reader(ns, 0, 0, 1, 1)
    seen = drain(cons, 5)
    v, s = cons.cursor                       # checkpointed at step 5
    lost = drain(cons, 2)                    # consumed past the checkpoint...
    del cons                                 # ...then killed
    t0 = now()
    cons2 = reader(ns, 0, 0, 1, 1)
    cons2.restore_cursor(v, s)
    replay = drain(cons2, 7)
    recovery_latency = now() - t0
    assert replay[:2] == lost, "post-checkpoint window did not replay " \
                               "byte-identically"
    assert_exactly_once(seen + replay, "P", 0, 0, 12)
    report = fsck(ns)
    assert report.clean, report.summary()
    return ScenarioResult(name="consumer_midstep_kill", passed=True,
                          steps_delivered=12,
                          recovery_latency_s=recovery_latency,
                          fsck_clean_after=True)


@scenario("mixed_reader_midstep_kill")
def mixed_reader_midstep_kill(seed: int = 0) -> ScenarioResult:
    """Kill a multi-stream MixedReader mid-step; a replacement restores the
    composite checkpoint (mix position + every stream's cursor) and the
    deterministic schedule replays identically."""
    from repro.dataplane import Topology, open_dataplane

    store = MemoryObjectStore()
    session = open_dataplane(store, Topology(dp=1, cp=1), backend="tgb",
                             namespace=CHAOS_PREFIX,
                             streams={"a": 2.0, "b": 1.0}, mix_seed=seed)
    total = 12
    counts = session.plan.stream_counts(total)
    for name in session.stream_names:
        with session.writer(f"w-{name}", stream=name) as w:
            for off in range(counts[name]):
                w.write(slices={(0, 0): deterministic_payload(name, off)})
    expected = []
    for g in range(total):
        name, s_step = session.plan.position(g)
        expected.append(deterministic_payload(name, s_step))

    r = session.reader()
    seen = [r.next_batch(timeout_s=10.0) for _ in range(5)]
    token = r.checkpoint()                   # composite: mix pos + cursors
    lost = [r.next_batch(timeout_s=10.0) for _ in range(2)]
    r.close()                                # killed mid-step
    t0 = now()
    r2 = session.reader(resume=token)
    replay = [r2.next_batch(timeout_s=10.0) for _ in range(total - 5)]
    recovery_latency = now() - t0
    got = [b.payload for b in seen + replay]
    assert [b.payload for b in replay[:2]] == [b.payload for b in lost], \
        "post-checkpoint mixed window did not replay identically"
    assert got == expected, "mixed exactly-once violated (payload mismatch)"
    sched = [session.plan.position(g)[0] for g in range(total)]
    assert [b.stream for b in seen + replay] == sched, \
        "stream routing diverged from the deterministic schedule"
    report = fsck(Namespace(store, CHAOS_PREFIX))
    assert report.clean, report.summary()
    session.close()
    return ScenarioResult(name="mixed_reader_midstep_kill", passed=True,
                          steps_delivered=total,
                          recovery_latency_s=recovery_latency,
                          fsck_clean_after=True)


@scenario("reclaimer_midtrim_kill")
def reclaimer_midtrim_kill(seed: int = 0) -> ScenarioResult:
    """Kill the reclaimer halfway through physical deletion; a restarted
    reclaimer completes idempotently and every checkpoint-needed step
    survives."""
    ns = fresh_ns()
    p = Producer(ns, "P", dp=1, cp=1)
    produce_range(p, 12)
    v_latest = ManifestStore(ns).latest_version()
    write_watermark(ns, 0, Watermark(version=v_latest, step=8))
    ns.store.faults.crash_on("delete", "/tgb/", nth=3)
    crashed = False
    try:
        Reclaimer(ns, expected_ranks=1).run_cycle()
    except InjectedCrash:
        crashed = True
    assert crashed, "delete crash rule never fired"
    ns.store.faults = None
    t0 = now()
    r2 = Reclaimer(ns, expected_ranks=1)
    r2.run_cycle()
    recovery_latency = now() - t0
    # steps >= 8 survive and replay exactly from the checkpoint cursor
    cons = reader(ns, 0, 0, 1, 1)
    cons.restore_cursor(v_latest, 8)
    got = drain(cons, 4)
    want = [deterministic_payload("P", off, 0, 0) for off in range(8, 12)]
    assert got == want, "checkpoint-needed steps were damaged by the trim"
    # everything below the watermark is gone (both cycles together)
    remaining = ns.store.list(ns.key("tgb"))
    assert len(remaining) == 4, f"expected 4 surviving TGBs, found " \
                                f"{len(remaining)}"
    report = fsck(ns)
    assert report.clean, report.summary()
    return ScenarioResult(name="reclaimer_midtrim_kill", passed=True,
                          steps_delivered=4,
                          recovery_latency_s=recovery_latency,
                          fsck_clean_after=True)


@scenario("cput_conflict_storm")
def cput_conflict_storm(seed: int = 0) -> ScenarioResult:
    """Three producers force-committing every TGB while the store injects
    conditional-put 5xx — 60% of them *lost acks* (the put landed before the
    'failure'). The rebase + ambiguity-resolution machinery must keep every
    stream gap-free and duplicate-free."""
    inner = MemoryObjectStore()
    store = FaultyObjectStore(inner, FaultPolicy(
        seed=seed, cput_error_rate=0.3, cput_lost_ack_rate=0.6,
        key_filter=".manifest", max_faults=24))
    ns = Namespace(store, CHAOS_PREFIX)
    n_producers, per = 3, 6
    producers = [Producer(ns, f"P{i}", dp=1, cp=1) for i in range(n_producers)]
    errs = []

    def body(p: Producer):
        try:
            produce_range(p, per)
        except Exception as e:  # surfaced after join
            errs.append((p.producer_id, e))

    t0 = now()
    threads = [threading.Thread(target=body, args=(p,)) for p in producers]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    recovery_latency = now() - t0
    assert not errs, f"producers died in the storm: {errs}"

    clean_ns = Namespace(inner, CHAOS_PREFIX)
    view = latest_view(clean_ns)
    for i in range(n_producers):
        seqs = [t.producer_seq for t in view.tgbs
                if t.producer_id == f"P{i}"]
        assert seqs == list(range(per)), \
            f"P{i} stream corrupted under the storm: {seqs}"
    # drain everything; per-producer payload order must be exact
    cons = Consumer(clean_ns, MeshPosition(0, 0, 1, 1))
    per_pid: dict = {}
    for _ in range(n_producers * per):
        payload = cons.next_batch(timeout_s=10.0)
        pid, off = bytes(payload).split(b"|", 1)[0].decode().split(":")[:2]
        per_pid.setdefault(pid, []).append((int(off), payload))
    for i in range(n_producers):
        pid = f"P{i}"
        offs = [o for o, _ in per_pid[pid]]
        assert offs == list(range(per)), f"{pid} delivered {offs}"
        for off, payload in per_pid[pid]:
            assert payload == deterministic_payload(pid, off), \
                f"{pid}@{off} payload corrupted"
    report = fsck(clean_ns)
    assert report.clean, report.summary()
    conflicts = sum(p.stats.commit_conflicts for p in producers)
    return ScenarioResult(name="cput_conflict_storm", passed=True,
                          steps_delivered=n_producers * per,
                          recovery_latency_s=recovery_latency,
                          faults_injected=store.fault_stats.total,
                          fsck_clean_after=True,
                          detail=f"{conflicts} conflicts rebased")


@scenario("flaky_reads")
def flaky_reads(seed: int = 0) -> ScenarioResult:
    """Consumer survives 5xx, truncated range-GETs, slow reads, and stale
    windows: bounded retries + CRC verification deliver every batch
    byte-perfect."""
    inner = MemoryObjectStore()
    store = FaultyObjectStore(inner, FaultPolicy(
        seed=seed, get_error_rate=0.12, short_read_rate=0.12,
        slow_get_rate=0.1, slow_get_s=0.001, stale_read_rate=0.25,
        stale_depth=3, max_faults=80))
    ns = Namespace(store, CHAOS_PREFIX)
    produce_range(Producer(ns, "P", dp=1, cp=1), N_TGBS)
    t0 = now()
    cons = Consumer(ns, MeshPosition(0, 0, 1, 1))
    got = drain(cons, N_TGBS)
    elapsed = now() - t0
    assert_exactly_once(got, "P", 0, 0, N_TGBS)
    report = fsck(Namespace(inner, CHAOS_PREFIX))
    assert report.clean, report.summary()
    return ScenarioResult(name="flaky_reads", passed=True,
                          steps_delivered=N_TGBS,
                          recovery_latency_s=elapsed,
                          faults_injected=store.fault_stats.total,
                          fsck_clean_after=True,
                          detail=f"{cons.stats.read_retries} read retries")


@scenario("trainer_midcheckpoint_kill")
def trainer_midcheckpoint_kill(seed: int = 0) -> ScenarioResult:
    """Kill the trainer *between* the model-state upload and the RunManifest
    commit — the exact window that silently broke exactly-once when model
    and data cursors were two separate saves. The RunManifest makes the
    commit the atom: recovery resumes from the previous *aligned* checkpoint
    (old model + old cursor together), replays the lost window
    byte-identically, and the half-uploaded model surfaces as a safe orphan
    once a later aligned checkpoint supersedes it."""
    from repro.core import FaultInjector

    n = 12
    store = MemoryObjectStore(faults=FaultInjector())
    ns = Namespace(store, CHAOS_PREFIX)
    p = Producer(ns, "P", dp=1, cp=1)
    p.recover()
    produce_range(p, n)

    sess = TrainSession(store, Topology(dp=1, cp=1), namespace=CHAOS_PREFIX)
    r = sess.reader(0, 0)
    seen = [r.next_batch(timeout_s=10).payload for _ in range(4)]
    state1 = {"w": np.arange(8, dtype=np.float32) + seed}
    entry = sess.checkpoint(state1)            # aligned @ step 4 (seq 0)
    assert entry.step == 4
    lost = [r.next_batch(timeout_s=10).payload for _ in range(2)]  # steps 4,5

    # the fatal window: model for step 6 uploads, the RunManifest put dies
    store.faults.crash_on("cput", key_substr=".rm", nth=1, phase="before")
    try:
        sess.checkpoint({"w": state1["w"] * -1.0})
        raise AssertionError("crash between upload and commit never fired")
    except InjectedCrash:
        pass
    store.faults = None

    t0 = now()
    sess2 = TrainSession.resume(store, CHAOS_PREFIX)
    assert sess2.resume_step == 4, \
        f"resume landed at {sess2.resume_step}, not the aligned step 4"
    state = sess2.restore_model({"w": np.zeros(8, dtype=np.float32)})
    assert np.array_equal(np.asarray(state["w"]), state1["w"]), \
        "restored model is not the aligned (pre-crash) state"
    r2 = sess2.reader(0, 0)
    replay = [r2.next_batch(timeout_s=10).payload for _ in range(n - 4)]
    recovery_latency = now() - t0

    assert replay[:2] == lost, "post-checkpoint window did not replay " \
                               "byte-identically"
    assert_exactly_once(seen + replay, "P", 0, 0, n)

    # a later aligned checkpoint supersedes the torn step-6 upload; fsck then
    # flags it as a safe orphan and repairs to clean
    sess2.checkpoint(state)
    orphans, clean = audit_and_repair(ns)
    assert orphans >= 1, "expected the torn model upload to surface as orphan"
    assert clean, "fsck not clean after repair"
    return ScenarioResult(name="trainer_midcheckpoint_kill", passed=True,
                          steps_delivered=n,
                          recovery_latency_s=recovery_latency,
                          orphans_detected=orphans, faults_injected=1,
                          fsck_clean_after=True)


def _derive_fixture(store, seed: int):
    """Deterministic source stream + two-op graph (filter -> pack) under a
    chaos namespace; returns (ns, graph, source_topology)."""
    from repro.data.packing import GlobalBatchPacker
    from repro.graph import FilterOp, OpGraph, PackOp

    gb, sl, dp = 8, 16, 2
    ns = Namespace(store, CHAOS_PREFIX)
    packer = GlobalBatchPacker(gb, sl, dp, 1)
    p = Producer(ns.stream("raw"), "P", dp=dp, cp=1)
    p.recover()
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, 1 << 15, gb * sl * 6, dtype=np.int64).astype(np.int32)
    for batch in packer.add_tokens(toks):
        p.write_tgb(slice_payloads=batch.slices,
                    num_samples=batch.num_samples,
                    token_count=batch.token_count)
        p.maybe_commit(force=True)
    p.finalize()
    g = OpGraph("chaos-derive")
    g.add(FilterOp("evens", lambda rows: rows[:, 0] % 2 == 0),
          source="raw", output="rows")
    g.add(PackOp("pack", global_batch=4, seq_len=sl, dp=1, cp=1),
          source="rows", output="filtered")
    return ns, g, Topology(dp=dp, cp=1, global_batch=gb, seq_len=sl)


def _derived_objects(ns: Namespace) -> dict:
    """{relative tgb key: bytes} of the derived stream (byte-identity probe)."""
    sns = ns.stream("filtered")
    prefix = sns.key("tgb") + "/"
    return {k[len(prefix):]: bytes(sns.store.get(k))
            for k in sns.store.list(prefix)}


@scenario("derive_worker_midpublish_kill")
def derive_worker_midpublish_kill(seed: int = 0) -> ScenarioResult:
    """Kill the DeriveWorker *between* publishing a window's outputs (uploads
    + manifest commit done) and committing the derive cursor — the widest
    torn-progress window the protocol allows. The restarted worker replays
    the interrupted window from the previous cursor: every replayed output
    lands on its content address (upload skipped, counted as a store hit)
    and its manifest offset deduplicates, so the derived stream ends
    byte-identical to an uncrashed run with zero duplicates and zero
    re-derived TGBs persisted, and fsck audits clean."""
    from repro.core import FaultInjector
    from repro.graph import DeriveWorker

    n_src = 6
    # reference: the same derivation with no fault, in a pristine store
    ref_store = MemoryObjectStore()
    ref_ns, ref_g, topo = _derive_fixture(ref_store, seed)
    DeriveWorker(ref_ns, ref_g, topo, window_steps=2).run(
        max_source_steps=n_src, timeout_s=10)
    want = _derived_objects(ref_ns)

    store = MemoryObjectStore(faults=FaultInjector())
    ns, g, topo = _derive_fixture(store, seed)
    # 2nd derive-cursor conditional put dies before reaching the store:
    # window 2's outputs are fully published but its progress is not
    store.faults.crash_on("cput", key_substr=".dc", nth=2, phase="before")
    w = DeriveWorker(ns, g, topo, window_steps=2)
    try:
        w.run(max_source_steps=n_src, timeout_s=10)
        raise AssertionError("mid-publish crash never fired")
    except InjectedCrash:
        pass
    store.faults = None

    t0 = now()
    w2 = DeriveWorker(ns, g, topo, window_steps=2)
    stats = w2.run(max_source_steps=n_src, timeout_s=10)
    recovery_latency = now() - t0
    assert stats.resumed_src_step == 2, \
        f"restart resumed at src_step {stats.resumed_src_step}, expected 2"
    assert stats.store_hits >= 1, \
        "replayed window re-uploaded outputs instead of hitting the store"

    got = _derived_objects(ns)
    assert got == want, \
        f"derived stream diverged from the uncrashed run: " \
        f"{sorted(got)} vs {sorted(want)}"
    view = latest_view(ns.stream("filtered"))
    offs = [t.producer_seq for t in view.tgbs]
    assert offs == list(range(len(offs))), \
        f"derived offsets not contiguous/unique: {offs}"
    assert len(view.derived_tgbs()) == len(view.tgbs), \
        "derived TGB lost its provenance record"
    delivered = len(drain(reader(ns.stream("filtered"), 0, 0, 1, 1),
                          len(offs)))

    orphans, clean = audit_and_repair(ns)
    assert clean, "fsck not clean after derive-worker crash recovery"
    return ScenarioResult(name="derive_worker_midpublish_kill", passed=True,
                          steps_delivered=delivered,
                          recovery_latency_s=recovery_latency,
                          orphans_detected=orphans, faults_injected=1,
                          fsck_clean_after=True)


@scenario("brownout_throttle_storm")
def brownout_throttle_storm(seed: int = 0) -> ScenarioResult:
    """Two producers and a live consumer ride out a scripted 503 SlowDown
    storm behind the ``ResilientStore``: throttles feed the shared AIMD
    governor (collective backoff), Retry-After is honored, spilling absorbs
    retry-budget exhaustion, and the streams stay gap-free and
    duplicate-free."""
    inner = MemoryObjectStore()
    faulty = FaultyObjectStore(inner, FaultPolicy(seed=seed))
    store = ResilientStore(faulty, ResilienceConfig(
        seed=seed, base_delay_s=0.002, backoff_cap_s=0.05,
        breaker_failure_threshold=8, breaker_cooldown_s=0.05,
        governor_min_rate=20.0, governor_ai_per_s=100.0))
    ns = Namespace(store, CHAOS_PREFIX)
    n_producers, per = 2, 6
    producers = [Producer(ns, f"P{i}", dp=1, cp=1, spill_limit=per)
                 for i in range(n_producers)]
    errs: list = []

    def produce_body(p: Producer):
        try:
            p.recover()
            while p.next_offset < per:
                p.write_tgb(slice_payloads=make_slices(
                    p.producer_id, p.next_offset, p.dp, p.cp))
                p.maybe_commit(force=True)
            p.finalize()
        except Exception as e:
            errs.append((p.producer_id, e))

    got: list = []

    def consume_body():
        try:
            cons = Consumer(ns, MeshPosition(0, 0, 1, 1))
            for _ in range(n_producers * per):
                got.append(cons.next_batch(timeout_s=30.0))
        except Exception as e:
            errs.append(("consumer", e))

    # storm covers roughly the first half of the run: 60% of ops rejected
    # with Retry-After while it lasts
    faulty.script_brownout([BrownoutPhase(0.0, 0.4, throttle_rate=0.6,
                                          retry_after_s=0.004)])
    t0 = now()
    threads = [threading.Thread(target=produce_body, args=(p,))
               for p in producers]
    threads.append(threading.Thread(target=consume_body))
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    elapsed = now() - t0
    assert not errs, f"clients died in the storm: {errs}"

    throttled = faulty.fault_stats.counts.get("throttled", 0)
    assert throttled > 0, "storm never actually throttled anything"
    assert store.resilience.throttled > 0, \
        "resilience layer did not observe the throttles"
    assert store.governor.throttle_events > 0, \
        "AIMD governor never engaged during the storm"

    # per-producer stream integrity + exactly-once delivery
    clean_ns = Namespace(inner, CHAOS_PREFIX)
    view = latest_view(clean_ns)
    for i in range(n_producers):
        seqs = [t.producer_seq for t in view.tgbs
                if t.producer_id == f"P{i}"]
        assert seqs == list(range(per)), \
            f"P{i} stream corrupted by the storm: {seqs}"
    per_pid: dict = {}
    for payload in got:
        pid, off = bytes(payload).split(b"|", 1)[0].decode().split(":")[:2]
        per_pid.setdefault(pid, []).append(int(off))
    for i in range(n_producers):
        offs = per_pid.get(f"P{i}", [])
        assert offs == list(range(per)), f"P{i} delivered {offs}"
    report = fsck(clean_ns)
    assert report.clean, report.summary()
    spilled = sum(p.stats.tgbs_spilled for p in producers)
    replayed = sum(p.stats.spill_replayed for p in producers)
    assert spilled == replayed, \
        f"spill not fully replayed: {spilled} spilled, {replayed} replayed"
    return ScenarioResult(
        name="brownout_throttle_storm", passed=True,
        steps_delivered=n_producers * per, recovery_latency_s=elapsed,
        faults_injected=faulty.fault_stats.total, fsck_clean_after=True,
        detail=f"{throttled} throttles, {store.resilience.retries} retries, "
               f"{spilled} spilled")


@scenario("store_outage_resume")
def store_outage_resume(seed: int = 0) -> ScenarioResult:
    """The store disappears entirely mid-run. The shared circuit breaker
    flips both clients into degraded mode: the consumer keeps serving
    already-prefetched TGBs (zero store round trips), the producer spills
    built TGBs into its bounded queue; on recovery the spill replays in
    producer_seq order, commits dedup exactly-once, and fsck is clean."""
    inner = MemoryObjectStore()
    faulty = FaultyObjectStore(inner, FaultPolicy(seed=seed))
    store = ResilientStore(faulty, ResilienceConfig(
        seed=seed, read_attempts=2, write_attempts=2, base_delay_s=0.002,
        backoff_cap_s=0.02, breaker_failure_threshold=3,
        breaker_cooldown_s=0.05))
    ns = Namespace(store, CHAOS_PREFIX)
    pre, during, total = 3, 4, 7

    p = Producer(ns, "P", dp=1, cp=1, spill_limit=during)
    p.recover()
    for _ in range(pre):
        p.write_tgb(slice_payloads=make_slices("P", p.next_offset, 1, 1))
        p.maybe_commit(force=True)

    cons = Consumer(ns, MeshPosition(0, 0, 1, 1), prefetch_depth=4)
    cons.poll()
    cons.start_prefetch()
    deadline = now() + 10.0
    while now() < deadline:
        with cons._prefetch_lock:
            if len(cons._prefetched) >= pre:
                break
        inner.clock.sleep(0.002)
    with cons._prefetch_lock:
        warm = len(cons._prefetched)
    assert warm >= pre, f"prefetch only warmed {warm}/{pre} steps"

    # lights out: every op fails until the script is cleared
    faulty.script_brownout([BrownoutPhase(0.0, 3600.0, outage=True)])
    t0 = now()
    for _ in range(during):
        p.write_tgb(slice_payloads=make_slices("P", p.next_offset, 1, 1))
        p.maybe_commit()
    assert p.spilled == during, \
        f"expected {during} spilled TGBs, got {p.spilled}"
    assert p.stats.store_degraded == 1.0
    got = drain(cons, pre, timeout_s=30.0)  # served from prefetch, store down
    assert store.degraded, "breaker never opened during the outage"
    assert cons.stats.degraded_batches > 0, \
        "degraded-mode service not surfaced in consumer obs"
    assert cons.stats.store_degraded == 1.0

    # recovery: clear the script, replay the spill, drain the rest
    faulty.clear_brownout()
    p.finalize()
    recovery_latency = now() - t0
    assert p.spilled == 0 and p.stats.spill_replayed == during, \
        f"spill replay incomplete: {p.spilled} left, " \
        f"{p.stats.spill_replayed} replayed"
    got += drain(cons, total - pre, timeout_s=30.0)
    cons.stop_prefetch()
    assert_exactly_once(got, "P", 0, 0, total)

    clean_ns = Namespace(inner, CHAOS_PREFIX)
    view = latest_view(clean_ns)
    seqs = [t.producer_seq for t in view.tgbs]
    assert seqs == list(range(total)), \
        f"replayed stream not in producer_seq order: {seqs}"
    report = fsck(clean_ns)
    assert report.clean, report.summary()
    outages = faulty.fault_stats.counts.get("outage", 0)
    return ScenarioResult(
        name="store_outage_resume", passed=True, steps_delivered=total,
        recovery_latency_s=recovery_latency, faults_injected=outages,
        fsck_clean_after=True,
        detail=f"{during} spilled+replayed, "
               f"{cons.stats.degraded_batches} degraded batches, "
               f"breaker opened {store.breaker.opens}x")


@scenario("shard_conflict_storm")
def shard_conflict_storm(seed: int = 0) -> ScenarioResult:
    """Six producers force-committing onto a 4-shard manifest plane while the
    store injects conditional-put 5xx (60% lost acks). The per-shard rebase
    machinery, the DAC shard chooser, and the cross-shard dedup must keep the
    merged global step sequence gap-free and duplicate-free."""
    from repro.core import write_shard_config

    inner = MemoryObjectStore()
    store = FaultyObjectStore(inner, FaultPolicy(
        seed=seed, cput_error_rate=0.3, cput_lost_ack_rate=0.6,
        key_filter=".manifest", max_faults=32))
    ns = Namespace(store, CHAOS_PREFIX)
    write_shard_config(ns, 4)  # claim the layout before any client starts
    n_producers, per = 6, 5
    producers = [Producer(ns, f"P{i}", dp=1, cp=1) for i in range(n_producers)]
    errs = []

    def body(p: Producer):
        try:
            produce_range(p, per)
        except Exception as e:  # surfaced after join
            errs.append((p.producer_id, e))

    t0 = now()
    threads = [threading.Thread(target=body, args=(p,)) for p in producers]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    recovery_latency = now() - t0
    assert not errs, f"producers died in the storm: {errs}"

    clean_ns = Namespace(inner, CHAOS_PREFIX)
    view = latest_view(clean_ns)
    for i in range(n_producers):
        seqs = [t.producer_seq for t in view.tgbs
                if t.producer_id == f"P{i}"]
        assert seqs == list(range(per)), \
            f"P{i} stream corrupted under the storm: {seqs}"
    ids = [t.tgb_id for t in view.tgbs]
    assert len(set(ids)) == len(ids), "duplicate TGB in the merged sequence"
    # drain everything through the merged view; per-producer payload order
    # must be exact (the merged order interleaves producers, the per-producer
    # subsequences may not)
    cons = Consumer(clean_ns, MeshPosition(0, 0, 1, 1))
    per_pid: dict = {}
    for _ in range(n_producers * per):
        payload = cons.next_batch(timeout_s=10.0)
        pid, off = bytes(payload).split(b"|", 1)[0].decode().split(":")[:2]
        per_pid.setdefault(pid, []).append((int(off), payload))
    for i in range(n_producers):
        pid = f"P{i}"
        offs = [o for o, _ in per_pid[pid]]
        assert offs == list(range(per)), f"{pid} delivered {offs}"
        for off, payload in per_pid[pid]:
            assert payload == deterministic_payload(pid, off), \
                f"{pid}@{off} payload corrupted"
    report = fsck(clean_ns)
    assert report.clean, report.summary()
    conflicts = sum(p.stats.commit_conflicts for p in producers)
    switches = sum(int(p.protocol.stats.switches) for p in producers)
    return ScenarioResult(name="shard_conflict_storm", passed=True,
                          steps_delivered=n_producers * per,
                          recovery_latency_s=recovery_latency,
                          faults_injected=store.fault_stats.total,
                          fsck_clean_after=True,
                          detail=f"{conflicts} conflicts rebased, "
                                 f"{switches} shard switches")


@scenario("compactor_midfold_kill")
def compactor_midfold_kill(seed: int = 0) -> ScenarioResult:
    """Kill the compactor between writing a segment and issuing the shard
    trim commits (the mid-fold crash window). Readers must deduplicate the
    folded-but-untrimmed prefix (no duplicate steps, no gaps), fsck must
    report the lagging trims as a repairable warning — not an error — and a
    restarted compactor must repair idempotently to a clean state."""
    from repro.core import Compactor, open_manifest_store, write_shard_config

    ns = fresh_ns()
    write_shard_config(ns, 4)
    n_producers, per = 3, 8
    producers = [Producer(ns, f"P{i}", dp=1, cp=1) for i in range(n_producers)]
    for p in producers:
        produce_range(p, per)
    total = n_producers * per

    manifests = open_manifest_store(ns)
    comp = Compactor(ns, manifests, min_fold=1)
    first = comp.run_cycle(safe_step=total // 2)
    assert first["segment"] == 0 and first["folded"] > 0, first

    # arm the kill: the next conditional put on any shard chain (= the first
    # trim commit of the next cycle) crashes; the segment (under manifest/
    # compact/) is already durable at that point
    ns.store.faults.crash_on("cput", "shard-", nth=1, phase="before")
    t0 = now()
    crashed = False
    try:
        comp.run_cycle(safe_step=total)
    except InjectedCrash:
        crashed = True
    assert crashed, "trim crash rule never fired"
    ns.store.faults = None

    # crash window: folds are ahead of every shard base. A cold reader must
    # still see each step exactly once, and fsck must call it repairable.
    cold = open_manifest_store(ns)
    mv = cold.load_view(cold.latest_version())
    ids = [t.tgb_id for t in mv.tgbs]
    assert mv.total_steps == total, (mv.total_steps, total)
    assert len(set(ids)) == len(ids), "crash window duplicated steps"
    report = fsck(ns)
    kinds = {i.kind for i in report.issues}
    assert "compaction-lagging-trim" in kinds, sorted(kinds)
    assert not any(i.severity == "error" for i in report.issues), \
        report.summary()

    # operator restart: a fresh compactor's repair pass re-issues the trims
    comp2 = Compactor(ns, open_manifest_store(ns), min_fold=1)
    s = comp2.run_cycle(safe_step=total)
    recovery_latency = now() - t0
    assert s["repaired"] > 0, s
    report2 = fsck(ns)
    assert report2.clean, report2.summary()
    assert "compaction-lagging-trim" not in {i.kind for i in report2.issues}

    # full drain after repair: the global sequence is intact end to end
    cons = Consumer(ns, MeshPosition(0, 0, 1, 1))
    per_pid: dict = {}
    for _ in range(total):
        payload = cons.next_batch(timeout_s=10.0)
        pid, off = bytes(payload).split(b"|", 1)[0].decode().split(":")[:2]
        per_pid.setdefault(pid, []).append(int(off))
    for i in range(n_producers):
        assert per_pid[f"P{i}"] == list(range(per)), per_pid
    return ScenarioResult(name="compactor_midfold_kill", passed=True,
                          steps_delivered=total,
                          recovery_latency_s=recovery_latency,
                          fsck_clean_after=True,
                          detail=f"fold crashed after segment "
                                 f"{first['segment'] + 1} write, "
                                 f"{s['repaired']} shards repaired")
