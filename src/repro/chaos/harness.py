"""Chaos harness scaffolding: scenario registry, result type, shared helpers.

A *scenario* is a self-contained failure-isolation experiment: build a fresh
in-memory run, kill a component at a named protocol point (via
``FaultInjector`` crash rules or a ``FaultyObjectStore`` fault policy),
restart/replace it, then assert the paper's §5 guarantees survived:

  * **exactly-once delivery** — every global batch is delivered exactly once
    with byte-identical payloads (sources are deterministic by
    ``(producer_id, offset, d, c)``, so replays are comparable);
  * **atomic all-rank visibility** — every rank converges on the same
    published frontier, and no rank ever observes a torn batch;
  * **no orphaned objects after recovery** — ``repro.ops.fsck`` accounts for
    every byte: crash leftovers are detected as safe orphans, repaired, and
    the namespace then audits clean.

Scenarios register with :func:`scenario` and run via :func:`run_scenario` /
:func:`run_all` (or ``python -m repro.chaos``).
"""
from __future__ import annotations

import time
import traceback
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import (Consumer, FaultInjector, ManifestStore,
                        MemoryObjectStore, MeshPosition, Namespace, Producer,
                        open_manifest_store)
from repro.ops import fsck

__all__ = ["SCENARIOS", "ScenarioResult", "scenario", "run_scenario",
           "run_all", "deterministic_payload", "make_slices", "produce_range",
           "drain", "assert_exactly_once", "assert_all_ranks_converge",
           "audit_and_repair", "fresh_ns"]

CHAOS_PREFIX = "runs/chaos"


@dataclass
class ScenarioResult:
    """Outcome of one chaos scenario (all assertions already enforced)."""

    name: str
    passed: bool
    steps_delivered: int = 0
    recovery_latency_s: float = 0.0
    orphans_detected: int = 0
    faults_injected: int = 0
    fsck_clean_after: bool = False
    detail: str = ""

    def row(self) -> str:
        status = "PASS" if self.passed else "FAIL"
        return (f"{status}  {self.name:<34} steps={self.steps_delivered:<4} "
                f"recovery={self.recovery_latency_s * 1e3:7.1f}ms "
                f"orphans={self.orphans_detected} "
                f"faults={self.faults_injected} "
                f"fsck={'clean' if self.fsck_clean_after else 'DIRTY'}"
                + (f"  [{self.detail}]" if self.detail else ""))


SCENARIOS: Dict[str, Callable[[int], ScenarioResult]] = {}


def scenario(name: str):
    """Register a chaos scenario under ``name`` (callable: seed -> result)."""
    def deco(fn: Callable[[int], ScenarioResult]):
        SCENARIOS[name] = fn
        return fn
    return deco


def run_scenario(name: str, seed: int = 0) -> ScenarioResult:
    """Run one scenario; assertion/infrastructure failures become a failed
    result carrying the traceback tail instead of propagating."""
    fn = SCENARIOS[name]
    try:
        return fn(seed)
    except Exception as e:
        tb = traceback.format_exc().strip().splitlines()[-1]
        return ScenarioResult(name=name, passed=False,
                              detail=f"{type(e).__name__}: {e} ({tb})")


def run_all(only: Optional[List[str]] = None,
            seed: int = 0) -> List[ScenarioResult]:
    names = only if only else sorted(SCENARIOS)
    for n in names:
        if n not in SCENARIOS:
            raise KeyError(f"unknown scenario {n!r}; known: "
                           f"{', '.join(sorted(SCENARIOS))}")
    return [run_scenario(n, seed=seed) for n in names]


# ---------------------------------------------------------------------------
# Shared scenario building blocks
# ---------------------------------------------------------------------------

def fresh_ns(store=None) -> Namespace:
    """A fresh zero-latency in-memory run namespace (crash hooks armed)."""
    if store is None:
        store = MemoryObjectStore(faults=FaultInjector())
    return Namespace(store, CHAOS_PREFIX)


def deterministic_payload(pid: str, offset: int, d: int = 0, c: int = 0,
                          nbytes: int = 64) -> bytes:
    """Pure function of identity — a replayed producer regenerates the exact
    bytes, which is what makes exactly-once *payload* equality checkable."""
    stamp = f"{pid}:{offset}:{d}:{c}|".encode()
    return (stamp * (nbytes // len(stamp) + 1))[:nbytes]


def make_slices(pid: str, offset: int, dp: int, cp: int,
                nbytes: int = 64) -> Dict[Tuple[int, int], bytes]:
    return {(d, c): deterministic_payload(pid, offset, d, c, nbytes)
            for d in range(dp) for c in range(cp)}


def produce_range(producer: Producer, upto_offset: int,
                  nbytes: int = 64) -> None:
    """Drive ``producer`` until ``next_offset == upto_offset``, committing
    eagerly (every write force-commits, the worst case for the protocol)."""
    while producer.next_offset < upto_offset:
        producer.write_tgb(slice_payloads=make_slices(
            producer.producer_id, producer.next_offset, producer.dp,
            producer.cp, nbytes))
        producer.maybe_commit(force=True)
    producer.finalize()


def drain(cons: Consumer, n: int, timeout_s: float = 10.0) -> List[bytes]:
    return [cons.next_batch(timeout_s=timeout_s) for _ in range(n)]


def assert_exactly_once(got: List[bytes], pid: str, d: int, c: int,
                        n: int, nbytes: int = 64) -> None:
    """The delivered sequence must be exactly payload(0..n-1): no gap, no
    duplicate, no corruption."""
    want = [deterministic_payload(pid, off, d, c, nbytes) for off in range(n)]
    if got != want:
        for i, (g, w) in enumerate(zip(got, want)):
            if g != w:
                raise AssertionError(
                    f"exactly-once violated at step {i}: got "
                    f"{bytes(g[:24])!r}... want {bytes(w[:24])!r}...")
        raise AssertionError(
            f"exactly-once violated: {len(got)} batches delivered, "
            f"{len(want)} expected")


def assert_all_ranks_converge(consumers: List[Consumer]) -> None:
    """Atomic all-rank visibility: after a poll, every rank's view agrees on
    the published frontier and the manifest version that defines it."""
    for cons in consumers:
        cons.poll()
    frontiers = {c.view.total_steps for c in consumers}
    versions = {c.view.version for c in consumers}
    if len(frontiers) != 1 or len(versions) != 1:
        raise AssertionError(
            f"ranks diverged: frontiers={sorted(frontiers)} "
            f"versions={sorted(versions)} — manifest visibility is not "
            f"atomic across ranks")


def audit_and_repair(ns: Namespace) -> Tuple[int, bool]:
    """Run fsck, repair safe orphans, re-audit. Returns
    ``(orphans_detected, clean_after_repair)``."""
    before = fsck(ns)
    orphans = len(before.orphans) + sum(len(r.orphans)
                                        for r in before.streams.values())
    if orphans:
        fsck(ns, repair=True)
    after = fsck(ns)
    return orphans, after.clean


def now() -> float:
    return time.monotonic()


def latest_view(ns: Namespace):
    m = open_manifest_store(ns)
    return m.load_view(m.latest_version())


def reader(ns: Namespace, d: int, c: int, dp: int, cp: int,
           **kw) -> Consumer:
    return Consumer(ns, MeshPosition(d, c, dp, cp), **kw)
