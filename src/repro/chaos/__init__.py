"""``repro.chaos`` — the failure-isolation chaos harness.

Scripted kill/restart scenarios at named protocol points (pre-commit,
post-upload-pre-manifest, mid-step, mid-trim, conflict storm, flaky reads)
that assert BatchWeave's §5 guarantees hold through recovery: exactly-once
delivery, atomic all-rank visibility, and no unaccounted storage after an
``repro.ops`` fsck/repair.

Usage::

    from repro.chaos import run_all, run_scenario
    results = run_all()                      # every registered scenario
    r = run_scenario("producer_precommit_kill")
    assert r.passed

CLI::

    python -m repro.chaos                    # run all, table output
    python -m repro.chaos --only producer_precommit_kill   # CI smoke
"""
from repro.chaos.harness import (SCENARIOS, ScenarioResult, run_all,
                                 run_scenario, scenario)
from repro.chaos import scenarios as _scenarios  # noqa: F401 — registers all

__all__ = ["SCENARIOS", "ScenarioResult", "run_all", "run_scenario",
           "scenario"]
