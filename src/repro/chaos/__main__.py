"""``python -m repro.chaos`` — run the failure-isolation scenarios."""
from __future__ import annotations

import argparse
import json
import sys

from repro.chaos import SCENARIOS, run_all


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.chaos",
        description="BatchWeave chaos harness: scripted kill/restart "
                    "scenarios asserting exactly-once recovery, atomic "
                    "visibility, and clean fsck.")
    ap.add_argument("--only", default=None,
                    help="comma-separated scenario names (default: all)")
    ap.add_argument("--seed", type=int, default=0,
                    help="fault-injection seed (default 0)")
    ap.add_argument("--list", action="store_true", dest="list_only",
                    help="list scenario names and exit")
    ap.add_argument("--json", action="store_true", dest="as_json")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="enable span tracing for the run and write a "
                         "Chrome trace (chrome://tracing / Perfetto) to FILE")
    args = ap.parse_args(argv)

    if args.list_only:
        for name in sorted(SCENARIOS):
            print(name)
        return 0
    only = args.only.split(",") if args.only else None
    if args.trace:
        from repro.obs.tracer import enable_tracing
        enable_tracing()
    try:
        results = run_all(only=only, seed=args.seed)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    if args.trace:
        from repro.obs.tracer import TRACER
        TRACER.write_chrome_trace(args.trace)
        print(f"# chrome trace: {args.trace} ({len(TRACER.spans())} spans)",
              file=sys.stderr)
    if args.as_json:
        json.dump([vars(r) for r in results], sys.stdout, indent=2)
        print()
    else:
        for r in results:
            print(r.row())
        n_fail = sum(1 for r in results if not r.passed)
        print(f"# {len(results) - n_fail}/{len(results)} scenarios passed "
              f"(seed={args.seed})")
    return 0 if all(r.passed for r in results) else 1


if __name__ == "__main__":
    sys.exit(main())
